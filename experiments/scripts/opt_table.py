"""Baseline vs optimized-recipe roofline comparison over every train_4k /
prefill_32k cell (reads experiments/dryrun + experiments/perf __opt tags).
Recipe per family: attention archs = flash-kernel contract + seq-sharded
residuals (+ hierarchical MoE dispatch); hybrid = kernel only; ssm = n/a.
Honest compute: stub cells quote the baseline's compute term (same matmul
FLOPs) unless the variant legitimately changed compute (MoE dispatch)."""
import glob, json, sys
sys.path.insert(0, "src")
import numpy as np
from repro.analysis.roofline import cell_roofline

rows = []
for f in sorted(glob.glob('experiments/perf/*__opt.json')):
    rec = json.load(open(f))
    base = json.load(open(
        f"experiments/dryrun/{rec['arch']}__{rec['shape']}__pod1.json"))
    rb, ro = cell_roofline(base), cell_roofline(rec)
    comp = ro.compute_s
    if rec['overrides'].get('attn_impl') == 'stub' \
            and rec['overrides'].get('moe_dispatch') != 'dp':
        comp = rb.compute_s
    bound = max(comp, ro.memory_s, ro.collective_s)
    frac = ro.model_flops / (ro.chips * 197e12 * bound) if bound else 0
    rows.append((f"{rec['arch']} × {rec['shape']}", rb.bound_s, bound,
                 rb.bound_s / bound, rb.roofline_fraction, frac))

print("| cell | baseline bound_s | optimized bound_s | speedup | "
      "baseline frac | optimized frac |")
print("|---|---|---|---|---|---|")
for name, b, o, sp, fb, fo in rows:
    print(f"| {name} | {b:.2f} | {o:.3f} | {sp:.1f}× | {fb:.3f} | {fo:.3f} |")
print(f"\ngeomean speedup: "
      f"{np.exp(np.mean([np.log(r[3]) for r in rows])):.2f}x")
