"""Scenario-driven policy auto-tuning: per-scenario frontier + winner tables.

Searches the policy space (all 9 kinds x their parameter grids, coarse
grid + successive-halving refinement — ``repro.tuning``) for every
selected catalog scenario under a degradation budget, entirely on the
batched compiled pipeline, and prints each scenario's energy/degradation
Pareto frontier plus the minimum-energy policy that respects the budget.

Usage:
    python experiments/scripts/tune_policies.py [--scale tiny|small|paper]
        [--scenarios a,b,c | --families ml,hpc,dc,app] [--nodes N]
        [--budget PCT] [--rounds N] [--keep K] [--space default|tiny]
        [--objective link_energy|total_energy] [--max-group N] [--csv PATH]

Examples:
    # full catalog, 1% budget, 3 search rounds, 80-node Megafly
    python experiments/scripts/tune_policies.py

    # the datacenter family under a tight 0.2% budget, CSV out
    python experiments/scripts/tune_policies.py --families dc \\
        --budget 0.2 --csv tuned.csv
"""
import argparse
import contextlib
import csv
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from run_suite import get_topo

from repro import scenarios as SC
from repro import tuning
from repro.distributed import shard_sweep
from repro.scenarios.catalog import FAMILIES
from repro.traffic.plan import PACKINGS, format_cache_info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["tiny", "small", "paper"],
                    default="small")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated catalog names (default: all)")
    ap.add_argument("--families", default=None,
                    help=f"restrict to families, e.g. dc (have: "
                         f"{','.join(FAMILIES)})")
    ap.add_argument("--nodes", type=int, default=None,
                    help="rescale every scenario's allocation "
                         "(default: 8 tiny / catalog size otherwise)")
    ap.add_argument("--budget", type=float, default=1.0, metavar="PCT",
                    help="degradation budget: max exec overhead vs each "
                         "scenario's own baseline, percent")
    ap.add_argument("--rounds", type=int, default=3,
                    help="coarse round + successive-halving refinements")
    ap.add_argument("--keep", type=int, default=4,
                    help="survivors refined per scenario per round")
    ap.add_argument("--space", choices=["default", "tiny"],
                    default="default")
    ap.add_argument("--objective", choices=list(tuning.OBJECTIVES),
                    default="link_energy")
    ap.add_argument("--max-group", type=int, default=None,
                    help="cap policy-batch width (device memory)")
    ap.add_argument("--packing", choices=list(PACKINGS), default="pow2",
                    help="stacked-plan segment layout (ragged: size-class "
                         "caps + merged tails, same results)")
    ap.add_argument("--mesh", action="store_true",
                    help="shard the (trace, lane) grid over all visible "
                         "devices (repro.distributed.shard_sweep)")
    ap.add_argument("--csv", default=None, metavar="PATH")
    args = ap.parse_args()

    topo = get_topo(args.scale)
    names = None
    if args.scenarios:
        names = args.scenarios.split(",")
        for n in names:
            SC.get_scenario(n)           # fail loudly on unknown names
    elif args.families:
        names = []
        for f in args.families.split(","):
            members = SC.list_scenarios(f)
            if not members:
                sys.exit(f"unknown family {f!r}; have {sorted(FAMILIES)}")
            names += members
    n_nodes = args.nodes or (8 if args.scale == "tiny" else None)
    space = tuning.tiny_space() if args.space == "tiny" \
        else tuning.default_space()

    n_scen = len(names) if names is not None else len(SC.list_scenarios())
    n_cand = len(tuning.space_candidates(space)[0])
    print(f"# tuning {n_scen} scenarios x {n_cand} coarse candidates, "
          f"budget <= {args.budget:g}%, {args.rounds} rounds on "
          f"{topo.n_nodes}-node topology", flush=True)
    t0 = time.time()
    with shard_sweep.use_mesh() if args.mesh else contextlib.nullcontext():
        report = tuning.tune_scenarios(
            topo, names, budget_pct=args.budget, rounds=args.rounds,
            space=space, keep=args.keep, n_nodes=n_nodes,
            objective=args.objective, max_group=args.max_group,
            packing=args.packing)
    print(f"# search done in {time.time() - t0:.1f}s; per-round "
          f"(cells, compiles): "
          f"{[(r['cells'], r['compiles']) for r in report.rounds]}",
          flush=True)
    print(f"# {format_cache_info()}", flush=True)
    print(tuning.format_report(report))
    rows = list(tuning.report_rows(report))
    if args.csv and rows:
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
        print(f"# wrote {len(rows)} rows to {args.csv}")


if __name__ == "__main__":
    main()
