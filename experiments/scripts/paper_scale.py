"""Paper-scale validation: the exact §4 scenario (4160-node Megafly,
64-node app traces) for the headline policies.  Writes CSV to stdout.

Runs on the batched sweep engine: the four headline policies collapse into
three static-structure groups (both fixed-t_PDT variants share one batched
replay).  ``max_group`` caps the policy-batch width so predictor state
(O(B x 10400 links x 200 bins) f64) stays bounded at paper scale."""
import sys, time
sys.path.insert(0, "src")
from repro.core.eee import Policy, PowerModel
from repro.core.simulator import compare_policies
from repro.topology.megafly import paper_topology
from repro.traffic import generators as G

topo = paper_topology()
pm = PowerModel()
pols = {
    "fixed_fw_100us": Policy(kind="fixed", t_pdt=100e-6, sleep_state="fast_wake"),
    "fixed_ds_100us": Policy(kind="fixed", t_pdt=100e-6, sleep_state="deep_sleep"),
    "pb_ds_1pct": Policy(kind="perfbound", bound=0.01, sleep_state="deep_sleep"),
    "pbc_ds_1pct": Policy(kind="perfbound_correct", bound=0.01, sleep_state="deep_sleep"),
}
apps = {
    "patmos": G.patmos(topo, n_nodes=64, compute_secs=1285.0),
    "alexnet": G.alexnet(topo, n_nodes=64, iters=10),
    "lammps": G.lammps(topo, n_nodes=64, iters=40),
    "mlwf": G.mlwf(topo, n_nodes=64, steps=25, layers=8),
}
print("app,policy,exec_oh_pct,lat_oh_pct,saved_pct,link_saved_pct,miss_rate", flush=True)
for app, tr in apps.items():
    t0 = time.time()
    out = compare_policies(tr, topo, pols, pm, max_group=8)
    for name, r in out.items():
        mr = r["misses"] / max(r["hits"] + r["misses"], 1)
        print(f"{app},{name},{r['exec_overhead_pct']:.3f},"
              f"{r['latency_overhead_pct']:.2f},{r['energy_saved_pct']:.2f},"
              f"{r['link_energy_saved_pct']:.2f},{mr:.3f}", flush=True)
    print(f"# {app} done in {time.time()-t0:.0f}s", flush=True)
