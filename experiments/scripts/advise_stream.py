"""Streaming power advisor timeline: closed-loop policy switching on a
drifting dc-* stream (DESIGN.md §11).

Runs the online advisor on the named drift-catalog streams and prints a
per-window markdown timeline — arrival rate, the incumbent that served
the window, its overhead/savings vs the window's own always-on baseline,
switches, compile counts — plus the stream-level regret summary: energy
saved online vs the best single static policy in hindsight.

Usage:
  PYTHONPATH=src python experiments/scripts/advise_stream.py \
      [--drift drift-dc-regimes] [--budget 0.1] [--windows 10] \
      [--n-nodes 8] [--tiny] [--json OUT.json]
"""
import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.core.eee import Policy                          # noqa: E402
from repro.launch.power_advisor import advise_stream       # noqa: E402
from repro.topology.megafly import small_topology          # noqa: E402

# Same fixed racing pool as benchmarks/bench_stream.py: the aggressive /
# mild / two-stage regimes the drift catalog flips between.  Drop the
# --pool-tuned flag in to seed from tune_scenarios winners instead.
POOL = {
    "fixed-ds-1us": Policy(kind="fixed", t_pdt=1e-6,
                           sleep_state="deep_sleep"),
    "fixed-fw-100us": Policy(kind="fixed", t_pdt=1e-4,
                             sleep_state="fast_wake"),
    "dual-10us-200us": Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                              sleep_state="fast_wake",
                              deep_state="deep_sleep"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--drift", default="drift-dc-regimes")
    ap.add_argument("--budget", type=float, default=0.1)
    ap.add_argument("--windows", type=int, default=None)
    ap.add_argument("--n-nodes", type=int, default=None)
    ap.add_argument("--tiny", action="store_true",
                    help="12-node Megafly + 8-node stream (CI smoke)")
    ap.add_argument("--pool-tuned", action="store_true",
                    help="seed the pool from tune_scenarios winners "
                         "instead of the fixed racing pool")
    ap.add_argument("--json", default=None, metavar="OUT")
    args = ap.parse_args()

    topo = small_topology(n_groups=3, leaves=2, spines=2,
                          nodes_per_leaf=2) if args.tiny else None
    out = advise_stream(
        args.drift, budget_pct=args.budget, topo=topo,
        n_nodes=8 if args.tiny and args.n_nodes is None else args.n_nodes,
        windows=args.windows,
        pool=None if args.pool_tuned else POOL)

    print(f"### {out['stream']} ({out['drift']}, {out['windows']} windows, "
          f"budget <= {out['budget_pct']:g}% overhead)\n")
    print("| w | rate/s | incumbent | ovh% | saved% | compiles | switch |")
    print("|---|---|---|---|---|---|---|")
    for r in out["timeline"]:
        sw = (f"→ {r['next_incumbent']} ({r['reason']})"
              if r["switched"] else "")
        print(f"| {r['window']} | {r['rate']:.0f} | {r['incumbent']} | "
              f"{r['overhead_pct']:.3f} | {r['saved_pct']:.2f} | "
              f"{r['compiles']} | {sw} |")
    t = out["totals"]
    print(f"\nswitches: {out['switches']}")
    print(f"online:      link energy saved {t['online_saved_pct']:.2f}% "
          f"(overhead {t['online_overhead_pct']:.3f}%)")
    print(f"best static: link energy saved {t['best_static_saved_pct']:.2f}%"
          f" ({t['best_static']})")
    print(f"gain vs best-static-in-hindsight: "
          f"{t['gain_vs_static_pct']:.2f}%")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True, default=str)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
