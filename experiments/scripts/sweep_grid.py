"""Dense beyond-paper policy grid on the batched sweep engine.

The paper's §4 evaluation samples 9 fixed t_PDT points and 3 PerfBound
bounds; per-policy serial replay made anything denser impractical.  The
batched engine removes that constraint: this script sweeps

  * a 25-point log-spaced fixed t_PDT curve x 2 sleep states (ONE batched
    replay per app — all 50 cells share static structure), and
  * a 12-point bound curve for PerfBound and PerfBoundCorrect x 2 sleep
    states (one batched replay per kind),

and prints per-cell CSV plus the per-app energy-optimal cell.  Usage:

    python experiments/scripts/sweep_grid.py [small|paper] [n_nodes]
"""
import sys, time
sys.path.insert(0, "src")
import numpy as np

from repro.core.eee import Policy, PowerModel
from repro.core.sweep import group_policies, sweep_policies
from repro.topology.megafly import paper_topology, small_topology
from repro.traffic import generators as G
from repro.traffic.plan import compile_plan

scale = sys.argv[1] if len(sys.argv) > 1 else "small"
if scale not in ("small", "paper"):
    sys.exit(f"usage: sweep_grid.py [small|paper] [n_nodes] "
             f"(got scale={scale!r})")
n_nodes = int(sys.argv[2]) if len(sys.argv) > 2 else (64 if scale == "paper"
                                                      else 16)
topo = paper_topology() if scale == "paper" else small_topology()
pm = PowerModel()
apps = {
    "lammps": G.lammps(topo, n_nodes=n_nodes,
                       iters=40 if scale == "paper" else 10),
    "alexnet": G.alexnet(topo, n_nodes=n_nodes,
                         iters=10 if scale == "paper" else 3),
}

grid = {}
for st in ("fast_wake", "deep_sleep"):
    for t in np.geomspace(1e-7, 1.0, 25):
        grid[f"fixed,{st},{t:.3g}"] = Policy(kind="fixed", t_pdt=float(t),
                                             sleep_state=st)
    for b in np.geomspace(0.002, 0.2, 12):
        for kind, tag in (("perfbound", "pb"), ("perfbound_correct", "pbc")):
            grid[f"{tag},{st},{b:.3g}"] = Policy(kind=kind, bound=float(b),
                                                 sleep_state=st)
# dual-mode FSM curves (DESIGN.md §6): demotion-timer sweep, coalescing
# window sweep, and the adaptive-demotion bound curve — the Fast Wake ->
# Deep Sleep ladder the single-state grid above cannot express
for td in np.geomspace(1e-5, 1e-2, 8):
    grid[f"dual,fw>ds,{td:.3g}"] = Policy(
        kind="dual", t_pdt=1e-5, t_dst=float(td), sleep_state="fast_wake",
        deep_state="deep_sleep")
for md in np.geomspace(1e-5, 1e-3, 6):
    grid[f"coalesce,fw>ds,{md:.3g}"] = Policy(
        kind="coalesce", t_pdt=1e-5, t_dst=2e-4, max_delay=float(md),
        max_frames=16, sleep_state="fast_wake", deep_state="deep_sleep")
for b in np.geomspace(0.002, 0.2, 8):
    grid[f"pbd,fw>ds,{b:.3g}"] = Policy(
        kind="perfbound_dual", bound=float(b), sleep_state="fast_wake",
        deep_state="deep_sleep")

print(f"# {len(grid)} grid cells in {len(group_policies(grid))} batched "
      f"groups", flush=True)
print("app,policy,makespan_s,mean_latency_s,link_energy_J,total_energy_J,"
      "asleep_frac,miss_rate", flush=True)
max_group = 8 if scale == "paper" else None
for app, tr in apps.items():
    # compile the trace plan once up front — EVERY policy group below
    # reuses it from the cache (routes + padding computed once per app)
    t0 = time.time()
    plan = compile_plan(tr, topo)
    print(f"# {plan.describe()} compiled in {time.time() - t0:.1f}s",
          flush=True)
    t0 = time.time()
    out = sweep_policies(tr, topo, grid, pm, max_group=max_group)
    for name, r in out.items():
        mr = r.misses / max(r.hits + r.misses, 1)
        print(f"{app},{name},{r.makespan:.6g},{r.mean_latency:.6g},"
              f"{r.link_energy:.6g},{r.total_energy:.6g},"
              f"{r.asleep_frac:.4f},{mr:.4f}", flush=True)
    best = min(out, key=lambda k: out[k].total_energy)
    print(f"# {app}: best={best} total_e={out[best].total_energy:.6g}J "
          f"({time.time() - t0:.0f}s for {len(grid)} cells)", flush=True)
