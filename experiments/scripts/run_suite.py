"""Scenario-suite sweep: the named catalog x a policy grid, batched.

Replays every selected scenario under every policy on the multi-trace
batched path (same-shape plans stack along the trace axis; each static
policy group runs the whole stack in one compiled program per segment
shape) and prints per-scenario energy/degradation tables — the paper's §4
protocol generalized over the scenario catalog.

Usage:
    python experiments/scripts/run_suite.py [--scale tiny|small|paper]
        [--scenarios a,b,c | --families ml,hpc,dc,app] [--nodes N]
        [--policies default|dense] [--max-group N] [--csv PATH]

Examples:
    # full catalog, representative 4-policy grid, 80-node Megafly
    python experiments/scripts/run_suite.py

    # the stochastic family under a dense 28-policy grid, paper topology
    python experiments/scripts/run_suite.py --scale paper --families dc \\
        --policies dense --csv suite.csv
"""
import argparse
import contextlib
import csv
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro import scenarios as SC
from repro.core.eee import Policy
from repro.core.sweep import group_policies
from repro.distributed import shard_sweep
from repro.topology.megafly import paper_topology, small_topology
from repro.traffic.plan import PACKINGS, format_cache_info


def get_topo(scale):
    if scale == "paper":
        return paper_topology()
    if scale == "tiny":
        return small_topology(n_groups=3, leaves=2, spines=2,
                              nodes_per_leaf=2)
    return small_topology()


def dense_grid():
    """Beyond-default: 10-point fixed t_PDT curve x 2 sleep states, a
    4-point bound curve for the three adaptive predictors, a 6-point
    demotion-timer curve for the dual-mode ladder, and a 4-point
    coalescing-window curve — one batched replay per kind."""
    grid = {}
    for st in ("fast_wake", "deep_sleep"):
        for t in np.geomspace(1e-6, 1e-2, 10):
            grid[f"fixed-{st}-{t:.2g}"] = Policy(
                kind="fixed", t_pdt=float(t), sleep_state=st)
    for b in (0.005, 0.01, 0.02, 0.05):
        grid[f"pb-{b:g}"] = Policy(kind="perfbound", bound=b)
        grid[f"pbc-{b:g}"] = Policy(kind="perfbound_correct", bound=b)
        grid[f"pbd-{b:g}"] = Policy(kind="perfbound_dual", bound=b,
                                    sleep_state="fast_wake",
                                    deep_state="deep_sleep")
    for td in np.geomspace(2e-5, 2e-3, 6):
        grid[f"dual-{td:.2g}"] = Policy(
            kind="dual", t_pdt=1e-5, t_dst=float(td),
            sleep_state="fast_wake", deep_state="deep_sleep")
    for md in np.geomspace(1e-5, 1e-3, 4):
        grid[f"coalesce-{md:.2g}"] = Policy(
            kind="coalesce", t_pdt=1e-5, t_dst=2e-4, max_delay=float(md),
            max_frames=16, sleep_state="fast_wake",
            deep_state="deep_sleep")
    return grid


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["tiny", "small", "paper"],
                    default="small")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated catalog names (default: all)")
    ap.add_argument("--families", default=None,
                    help="restrict to families, e.g. ml,dc")
    ap.add_argument("--nodes", type=int, default=None,
                    help="rescale every scenario's allocation "
                         "(default: 8 tiny / catalog size otherwise)")
    ap.add_argument("--policies", choices=["default", "dense"],
                    default="default")
    ap.add_argument("--max-group", type=int, default=None,
                    help="cap policy-batch width (device memory)")
    ap.add_argument("--packing", choices=list(PACKINGS), default="pow2",
                    help="stacked-plan segment layout (ragged: size-class "
                         "caps + merged tails, same results)")
    ap.add_argument("--mesh", action="store_true",
                    help="shard the (trace, lane) grid over all visible "
                         "devices (repro.distributed.shard_sweep)")
    ap.add_argument("--csv", default=None, metavar="PATH")
    args = ap.parse_args()

    topo = get_topo(args.scale)
    names = None
    if args.scenarios:
        names = args.scenarios.split(",")
        for n in names:
            SC.get_scenario(n)               # fail loudly on unknown names
    elif args.families:
        names = []
        for f in args.families.split(","):
            members = SC.list_scenarios(f)
            if not members:
                known = sorted({s.family for s in SC.catalog().values()})
                sys.exit(f"unknown family {f!r}; have {known}")
            names += members
    n_nodes = args.nodes or (8 if args.scale == "tiny" else None)
    grid = dense_grid() if args.policies == "dense" \
        else SC.default_policy_grid()

    n_scen = len(names) if names is not None else len(SC.list_scenarios())
    print(f"# {n_scen} scenarios x {len(grid)} policies "
          f"({len(group_policies(grid))} static groups) on "
          f"{topo.n_nodes}-node topology", flush=True)
    t0 = time.time()
    with shard_sweep.use_mesh() if args.mesh else contextlib.nullcontext():
        res = SC.run_suite(topo, scenarios=names, policies=grid,
                           n_nodes=n_nodes, max_group=args.max_group,
                           packing=args.packing)
    print(f"# suite done in {time.time() - t0:.1f}s", flush=True)
    print(f"# {format_cache_info()}", flush=True)
    print(SC.format_table(res))
    for sc, rows in res.items():
        best = min((p for p in rows if p != "baseline"),
                   key=lambda p: rows[p]["total_energy"], default=None)
        if best:
            print(f"# {sc}: best={best} "
                  f"saved={rows[best]['energy_saved_pct']:.2f}% "
                  f"overhead={rows[best]['exec_overhead_pct']:.2f}%")
    rows = list(SC.table_rows(res))
    if args.csv and rows:
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
        print(f"# wrote {len(rows)} rows to {args.csv}")


if __name__ == "__main__":
    main()
