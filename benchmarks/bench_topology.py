"""Beyond-paper: Megafly vs fat-tree under identical traffic + policies.

The paper (§2.6) notes BXIv3 supports both; its evaluation uses Megafly.
Same app trace, same policies, both topologies — compares hop counts,
wake-transition pressure (more hops = more ports to wake per packet, the
paper's own argument for Megafly's low diameter), and energy saved.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PM, Row, timed
from repro.core.eee import Policy
from repro.core.simulator import compare_policies
from repro.topology.fattree import FatTree
from repro.topology.megafly import small_topology
from repro.traffic.generators import alexnet


def run(scale: str = "small"):
    if scale == "paper":
        from repro.topology.fattree import paper_equivalent_fattree
        from repro.topology.megafly import paper_topology
        topos = {"megafly": paper_topology(),
                 "fattree": paper_equivalent_fattree()}
        n_nodes, iters = 64, 10
    else:
        topos = {"megafly": small_topology(),
                 "fattree": FatTree(k=8)}       # 128 nodes vs 80
        n_nodes, iters = 16, 3
    pols = {"pbc": Policy(kind="perfbound_correct", bound=0.01,
                          sleep_state="deep_sleep")}
    rows = []
    for name, topo in topos.items():
        tr = alexnet(topo, n_nodes=n_nodes, iters=iters)
        out, us = timed(compare_policies, tr, topo, pols, PM)
        r = out["pbc"]
        # mean hop count over the trace's flows
        src = np.concatenate([s.msgs[:, 0] for s in tr.steps
                              if s.msgs is not None])
        dst = np.concatenate([s.msgs[:, 1] for s in tr.steps
                              if s.msgs is not None])
        hops = topo.routes(src, dst)[2].mean()
        rows.append(Row(
            f"topology/{name}", us,
            f"nodes={topo.n_nodes} links={topo.n_links} "
            f"mean_hops={hops:.2f} lat_oh={r['latency_overhead_pct']:.2f}% "
            f"link_saved={r['link_energy_saved_pct']:.2f}% "
            f"wakes={r['n_wake_transitions']}"))
    return rows
