"""Auto-tuner throughput: the per-scenario frontier search end to end.

Measures what the tuning subsystem costs on top of a plain suite sweep:
wall time for (coarse grid + successive-halving refinement rounds) over a
scenario set, cells/s across all rounds, and — the cache contract — the
per-round compile counts.  The warm pass of the ``BENCH_tuner.json``
record must compile ZERO programs (the search is deterministic, so every
round's (T, B) program shapes repeat); ``check_compiles.py`` guards that
against ``baselines/compile_counts.json`` in the bench-smoke CI job.

Scales:
  * tiny  — the 4-scenario dc-* stack x the 12-candidate ``tiny_space``
    (all eight searched kinds, incl. the predictive precoalesce/predict
    FSMs), 2 rounds, 8-node allocations on the 12-node Megafly (CI
    smoke).
  * small — the dc-* + hpc-* families x the full ``default_space``,
    3 rounds on the 80-node Megafly.
  * paper — the whole catalog at 64-node allocations on the 4160-node
    Megafly.
"""
from __future__ import annotations

from benchmarks.common import PM, Row, get_topo, timed
from repro import tuning


def _setup(scale: str):
    if scale == "tiny":
        return (["dc-poisson", "dc-hotspot", "dc-onoff", "dc-incast"], 8,
                tuning.tiny_space(), 2)
    if scale == "paper":
        return None, 64, tuning.default_space(), 3
    return (["dc-poisson", "dc-hotspot", "dc-onoff", "dc-incast",
             "hpc-stencil3d", "hpc-stencil2d", "hpc-spectral"], None,
            tuning.default_space(), 3)


def n_policies(scale: str) -> int:
    return len(tuning.space_candidates(_setup(scale)[2])[0])


def run(scale: str):
    topo = get_topo(scale)
    names, n_nodes, space, rounds = _setup(scale)
    report, us = timed(tuning.tune_scenarios, topo, names,
                       budget_pct=1.0, rounds=rounds, space=space,
                       keep=3, n_nodes=n_nodes, pm=PM)
    cells = sum(r["cells"] for r in report.rounds)
    compiles = [r["compiles"] for r in report.rounds]
    rows = [Row("tuner/search", us,
                f"{len(report.scenarios)}scen_{cells}cells_"
                f"{cells / (us / 1e6):.2f}cells_per_s_"
                f"compiles{'-'.join(map(str, compiles))}")]
    for sc, t in report.scenarios.items():
        w = t.winner
        rows.append(Row(
            f"tuner/{sc}", us / len(report.scenarios),
            f"winner={w.name}_"
            f"linksaved{w.row['link_energy_saved_pct']:.2f}pct_"
            f"ovh{w.degradation:.3f}pct_frontier{len(t.frontier)}"))
    return rows
