"""Decoupled (Pallas-kernel) fast path vs coupled simulator: accuracy of
the first-order approximation and its speedup — the quantified trade of
DESIGN.md §3 (TPU-native rethink)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PM, Row, get_apps, get_topo, timed
from repro.core import decoupled as D
from repro.core import simulator as S
from repro.core.eee import Policy


def run(scale: str = "small"):
    topo = get_topo(scale)
    rows = []
    trace = get_apps(scale, topo)["alexnet"]
    (res0, events), us_base = timed(
        S.simulate_trace, trace, topo, Policy(kind="none"), PM, True)
    (streams), us_stream = timed(D.events_to_streams, events, topo.n_links,
                                 res0.makespan)
    gaps, durs, tail = streams

    for t_pdt in (1e-5, 1e-3):
        pol = Policy(kind="fixed", t_pdt=t_pdt, sleep_state="deep_sleep")
        coupled, us_c = timed(S.simulate_trace, trace, topo, pol, PM)
        coupled = coupled[0]
        dec, us_d = timed(D.evaluate_fixed, gaps, durs, tail, t_pdt, pol, PM)
        err = abs(dec["link_energy"] - coupled.link_energy) \
            / coupled.link_energy
        rows.append(Row(
            f"decoupled/alexnet/t={t_pdt:g}", us_d,
            f"energy_err={100*err:.2f}% "
            f"wake_err={abs(float(np.asarray(dec['n_wake']).sum()) - coupled.n_wake_transitions):.0f} "
            f"speedup_x={us_c/max(us_d,1):.1f} coupled_us={us_c:.0f}"))
    rows.append(Row("decoupled/stream_build", us_stream,
                    f"events={sum(len(e[0]) for e in events)}"))
    return rows
