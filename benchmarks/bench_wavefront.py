"""Serial vs plan-scheduled message-phase replay (DESIGN.md §10).

Times warm whole-plan replays of the two most message-phase-bound catalog
scenarios — ``dc-incast`` (bottleneck links, near-serial conflict chains)
and ``ml-qwen3-moe`` (alltoall dispatch bursts) — under the wavefront
modes.  The serial executor spends ``cap`` inner-scan iterations per
message step (``BUCKET_MIN`` = 64 pads far past the live message count at
these scales); mode ``on`` runs the plan-scheduled phase — the dynamic
valid-prefix loop or chained conflict-free waves, whichever the segment
cost model picks — and ``auto`` may additionally keep the scan.  The
``off/on`` ratio measures what the plan-time schedule buys end to end.
All modes replay bit-identical results (tests/test_wavefront.py).

Policies are grouped by static structure exactly like the sweep layer
(one compiled program per group), so each kind really exercises its own
executor: the adaptive ``perfbound`` group rides the prefix loop, the
FSM-only kinds pick prefix or chained waves.

Scales:
  * tiny  — 8-node allocations on the 12-node Megafly, 5-policy grid:
    the CI smoke lane (compile-count baseline ``wavefront``).
  * small — 16-node allocations on the 80-node Megafly.
  * paper — 64-node allocations on the 4160-node Megafly.
"""
from __future__ import annotations

import time

from benchmarks.common import PM, Row, get_topo
from repro.core import replay
from repro.core.eee import Policy
from repro.core.sweep import group_policies
from repro.scenarios.spec import build_trace
from repro.scenarios.suite import resolve
from repro.traffic.plan import compile_plan

SCENARIOS = ["dc-incast", "ml-qwen3-moe"]
MODES = ("off", "on", "auto")
REPS = {"tiny": 5, "small": 3, "paper": 1}


def _grid() -> dict:
    return {
        "none": Policy(kind="none"),
        "fixed": Policy(kind="fixed", t_pdt=1e-4, sleep_state="deep_sleep"),
        "perfbound": Policy(kind="perfbound", bound=0.01),
        "dual": Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
        "coalesce": Policy(kind="coalesce", t_pdt=1e-5, t_dst=2e-4,
                           max_delay=5e-5, max_frames=4,
                           sleep_state="fast_wake",
                           deep_state="deep_sleep"),
    }


def n_policies(scale: str) -> int:
    return len(_grid())


def _replay(plan, groups):
    t_end = 0.0
    for pols in groups:
        out = replay.replay_plan(plan, pols, PM)
        t_end = float(out[1][0])
    return t_end


def run(scale: str):
    topo = get_topo(scale)
    n_nodes = {"tiny": 8, "small": 16, "paper": 64}[scale]
    grid = _grid()
    groups = [[grid[n] for n in names] for names in group_policies(grid)]
    reps = REPS[scale]
    rows = []
    for name, spec in resolve(SCENARIOS, n_nodes=n_nodes).items():
        plan = compile_plan(build_trace(spec, topo), topo)
        widths = [(s.cap, s.wave_width, s.mean_live)
                  for s in plan.segments if s.cap]
        warm = {}
        for mode in MODES:
            with replay.wavefront_mode(mode):
                _replay(plan, groups)                 # cold (compile) pass
                t0 = time.perf_counter()
                for _ in range(reps):
                    t_end = _replay(plan, groups)
                warm[mode] = (time.perf_counter() - t0) * 1e6 / reps
            assert t_end > 0.0
        speedup = warm["off"] / warm["on"]
        rows.append(Row(
            f"wavefront/{name}", warm["on"],
            f"serial{warm['off'] / 1e3:.1f}ms_wave{warm['on'] / 1e3:.1f}ms_"
            f"auto{warm['auto'] / 1e3:.1f}ms_speedup{speedup:.2f}x"))
        rows.append(Row(
            f"wavefront/{name}/widths", 0.0,
            "W,live_vs_cap=" + "|".join(f"{w},{lv:.0f}of{c}"
                                        for c, w, lv in widths)))
    return rows
