"""Paper Figs 8/11/14/17: PerfBound vs PerfBoundCorrect — 3 degradation
thresholds x 3 histogram-management modes x 2 sleep states, per app.

Runs on the batched sweep engine (repro.core.sweep): the whole grid groups
by static structure and replays each trace a handful of times instead of
once per cell — one compiled scan per chunk per group.  The first app also
reports a ``sweep_speedup`` row timing the batched grid against the serial
per-policy replay (both ends cold, compiles included, as a fresh grid run
experiences them).

Headline validation targets: PerfBoundCorrect's latency overhead <=
PerfBound's at equal threshold (Figs 8c/11a: reduced 'to a third' for
PATMOS Deep Sleep); energy within a few % of PerfBound (sometimes better —
LAMMPS Deep Sleep flips an energy INCREASE into savings, §4.1.2/§5);
circular-buffer histograms give the worst overheads (§4.1.2).
"""
from __future__ import annotations

from benchmarks.common import (BOUNDS, HIST_MODES, PM, Row, SLEEP_STATES,
                               get_apps, get_topo, timed)
from repro.core.eee import Policy
from repro.core.simulator import compare_policies, simulate_trace
from repro.core.sweep import group_policies


def _grid_axes(scale: str):
    if scale == "paper":
        return BOUNDS, HIST_MODES
    if scale == "tiny":
        return [0.01], ["keep_all"]
    return [0.01, 0.05], ["keep_all", "circular"]


def n_policies(scale: str = "small") -> int:
    bounds, modes = _grid_axes(scale)
    # kinds x sleep states x bounds x modes + the 2 beyond-paper cells
    return 2 * 2 * len(bounds) * len(modes) + 2


def run(scale: str = "small"):
    topo = get_topo(scale)
    bounds, modes = _grid_axes(scale)
    rows = []
    for i, (name, trace) in enumerate(get_apps(scale, topo).items()):
        pols = {}
        for kind, tag in (("perfbound", "pb"), ("perfbound_correct", "pbc")):
            for st in SLEEP_STATES:
                for b in bounds:
                    for m in modes:
                        pols[f"{tag}/{st}/b={b:g}/{m}"] = Policy(
                            kind=kind, bound=b, hist_mode=m, sleep_state=st,
                            hist_clear_n=250, ring_n=250)
        # beyond-paper: log-spaced bins — the paper's fixed-width bins give
        # all 200 bins to one decade; log bins cover ns..10s uniformly
        pols["pbc/deep_sleep/b=0.01/log_bins"] = Policy(
            kind="perfbound_correct", bound=0.01, sleep_state="deep_sleep",
            hist_log_bins=True)
        # beyond-paper: exponential recency bias (the paper's §5 future-
        # work question) — old gaps fade at 0.98/sample
        pols["pbc/deep_sleep/b=0.01/decay98"] = Policy(
            kind="perfbound_correct", bound=0.01, sleep_state="deep_sleep",
            hist_decay=0.98)
        out, us = timed(compare_policies, trace, topo, pols, PM)
        for key, r in out.items():
            if key == "baseline":
                continue
            rows.append(Row(
                f"perfbound/{name}/{key}", us / max(len(pols), 1),
                f"exec_oh={r['exec_overhead_pct']:.2f}% "
                f"lat_oh={r['latency_overhead_pct']:.2f}% "
                f"saved={r['energy_saved_pct']:.2f}% "
                f"link_saved={r['link_energy_saved_pct']:.2f}% "
                f"miss_rate={r['misses']/max(r['hits']+r['misses'],1):.3f}"))
        if i == 0 and scale != "tiny":
            # serial baseline over the SAME workload — the grid plus the
            # always-on baseline compare_policies injects.  Serial runs are
            # per-policy compiled plan replays (B=1), so this row isolates
            # the value of the policy-batch axis; both sides share the
            # cached TracePlan and pay real compile bills for their own
            # program shapes.
            def _serial():
                return [simulate_trace(trace, topo, p, PM)[0]
                        for p in [Policy(kind="none"), *pols.values()]]
            _, us_serial = timed(_serial)
            n_groups = len(group_policies(pols))
            rows.append(Row(
                f"perfbound/{name}/sweep_speedup", us,
                f"batched={us/1e6:.1f}s serial={us_serial/1e6:.1f}s "
                f"speedup={us_serial/max(us, 1):.2f}x "
                f"policies={len(pols)} groups={n_groups}"))
    return rows
