"""Compile-count regression guard for the bench-smoke CI jobs.

Compares the warm-pass backend-compile counts recorded in fresh
``BENCH_<name>.json`` files (written by ``benchmarks.run --warm
--json-dir``) against the committed baselines and fails on growth — a warm
pass that suddenly compiles is a broken plan/program cache, the exact
regression class the compiled-pipeline work exists to prevent.

Usage:
  PYTHONPATH=src python -m benchmarks.check_compiles --json-dir DIR \
      [--scale tiny] [--baseline benchmarks/baselines/compile_counts.json] \
      [--update]

``--update`` rewrites the baseline from the fresh records (commit the
result when a legitimate change moves a count DOWN or adds a bench).
Shrinking counts only warn, so improvements don't block CI but show up in
the log for a baseline refresh.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                                "compile_counts.json")


def load_records(json_dir: str) -> dict:
    """{bench: record} from every BENCH_*.json in ``json_dir``."""
    records = {}
    for path in sorted(glob.glob(os.path.join(json_dir, "BENCH_*.json"))):
        with open(path) as f:
            rec = json.load(f)
        records[rec["bench"]] = rec
    return records


def check(records: dict, baseline: dict, scale: str):
    """Returns (failures, warnings, fresh-count dict for ``scale``)."""
    failures, warnings, fresh = [], [], {}
    base_scale = baseline.get(scale, {})
    for bench, rec in records.items():
        if rec.get("scale") != scale:
            warnings.append(f"{bench}: record is scale={rec.get('scale')!r},"
                            f" expected {scale!r} — skipped")
            continue
        warm = rec.get("compiles_warm")
        if warm is None:
            failures.append(f"{bench}: no warm pass in record "
                            f"(run benchmarks.run with --warm)")
            continue
        fresh[bench] = warm
        want = base_scale.get(bench)
        if want is None:
            warnings.append(f"{bench}: no committed baseline "
                            f"(warm compiles = {warm}); add with --update")
        elif warm > want:
            failures.append(f"{bench}: warm compiles grew {want} -> {warm}")
        elif warm < want:
            warnings.append(f"{bench}: warm compiles shrank {want} -> "
                            f"{warm}; refresh the baseline with --update")
    return failures, warnings, fresh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", required=True,
                    help="directory holding fresh BENCH_*.json records")
    ap.add_argument("--scale", default="tiny",
                    help="bench scale the records must match")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh records")
    args = ap.parse_args()

    records = load_records(args.json_dir)
    if not records:
        sys.exit(f"no BENCH_*.json records under {args.json_dir}")
    baseline = {}
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            baseline = json.load(f)

    failures, warnings, fresh = check(records, baseline, args.scale)
    for w in warnings:
        print(f"WARN  {w}")
    if args.update:
        baseline.setdefault(args.scale, {}).update(fresh)
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"updated {args.baseline}: {baseline[args.scale]}")
        return
    for msg in failures:
        print(f"FAIL  {msg}")
    if failures:
        sys.exit(1)
    print(f"compile counts OK for {sorted(fresh)} at scale={args.scale}")


if __name__ == "__main__":
    main()
