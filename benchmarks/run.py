"""Benchmark driver: one module per paper table/figure (+ beyond-paper).

Usage:
  PYTHONPATH=src python -m benchmarks.run [--scale tiny|small|paper]
      [--only X] [--warm] [--json-dir DIR]

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

With ``--json-dir`` every module additionally writes a machine-readable
``BENCH_<name>.json`` perf-trajectory record: cold (and, with ``--warm``,
second-run) wall time, backend-compile counts, and the module's policy-grid
size — so PRs can compare benchmark numbers across revisions (the CI
bench-smoke job uploads these as artifacts).
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time

MODULES = [
    ("power_table", "benchmarks.bench_power_table"),       # Tables 5/6
    ("inactivity", "benchmarks.bench_inactivity"),         # Fig 1
    ("traffic", "benchmarks.bench_traffic_profiles"),      # Figs 6/9/12/15
    ("fixed_pdt", "benchmarks.bench_fixed_pdt"),           # Figs 7/10/13/16
    ("perfbound", "benchmarks.bench_perfbound"),           # Figs 8/11/14/17
    ("decoupled", "benchmarks.bench_decoupled"),           # DESIGN.md §3
    ("kernels", "benchmarks.bench_kernels"),               # kernel parity
    ("llm_traffic", "benchmarks.bench_llm_traffic"),       # beyond paper
    ("topology", "benchmarks.bench_topology"),             # beyond paper
    ("scenario_suite", "benchmarks.bench_scenario_suite"),  # beyond paper
    ("tuner", "benchmarks.bench_tuner"),                   # beyond paper
    ("sharded_sweep", "benchmarks.bench_sharded_sweep"),   # beyond paper
    ("wavefront", "benchmarks.bench_wavefront"),           # DESIGN.md §10
    ("stream", "benchmarks.bench_stream"),                 # DESIGN.md §11
]


def _timed_run(mod, scale):
    from repro.core.instrument import count_compiles
    with count_compiles() as cc:
        t0 = time.time()
        rows = list(mod.run(scale))
        wall = time.time() - t0
    return rows, wall, cc.count


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["tiny", "small", "paper"],
                    default="small")
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys to run")
    ap.add_argument("--warm", action="store_true",
                    help="run each module twice; report the warm pass too "
                         "(plan + compile caches populated)")
    ap.add_argument("--json-dir", default=None, metavar="DIR",
                    help="write BENCH_<name>.json perf records to DIR")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.json_dir:
        os.makedirs(args.json_dir, exist_ok=True)

    print("name,us_per_call,derived")
    failures = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        record = {"bench": key, "scale": args.scale, "status": "ok",
                  "cold_wall_s": None, "warm_wall_s": None,
                  "compiles_cold": None, "compiles_warm": None,
                  "policy_count": None, "rows": []}
        try:
            mod = importlib.import_module(modname)
            n_pol = getattr(mod, "n_policies", None)
            if n_pol is not None:
                record["policy_count"] = n_pol(args.scale)
            rows, cold_s, cold_c = _timed_run(mod, args.scale)
            record.update(cold_wall_s=round(cold_s, 3), compiles_cold=cold_c)
            for row in rows:
                print(row.csv(), flush=True)
            record["rows"] = [{"name": r.name, "us_per_call": r.us_per_call,
                              "derived": r.derived} for r in rows]
            if args.warm:
                _, warm_s, warm_c = _timed_run(mod, args.scale)
                record.update(warm_wall_s=round(warm_s, 3),
                              compiles_warm=warm_c)
                print(f"# {key} warm: {warm_s:.1f}s "
                      f"({warm_c} compiles; cold {cold_s:.1f}s, "
                      f"{cold_c} compiles)", flush=True)
        except Exception as e:  # keep the suite going; report at the end
            failures.append((key, repr(e)))
            record.update(status="error", error=repr(e))
            print(f"{key}/ERROR,0.0,{e!r}", flush=True)
        print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
        if args.json_dir:
            path = os.path.join(args.json_dir, f"BENCH_{key}.json")
            with open(path, "w") as f:
                json.dump(record, f, indent=1, sort_keys=True)
    if failures:
        print(f"# {len(failures)} module(s) failed: {failures}")
        sys.exit(1)
    print("# all benchmark modules passed")


if __name__ == "__main__":
    main()
