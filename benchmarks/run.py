"""Benchmark driver: one module per paper table/figure (+ beyond-paper).

Usage:
  PYTHONPATH=src python -m benchmarks.run [--scale small|paper] [--only X]

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    ("power_table", "benchmarks.bench_power_table"),       # Tables 5/6
    ("inactivity", "benchmarks.bench_inactivity"),         # Fig 1
    ("traffic", "benchmarks.bench_traffic_profiles"),      # Figs 6/9/12/15
    ("fixed_pdt", "benchmarks.bench_fixed_pdt"),           # Figs 7/10/13/16
    ("perfbound", "benchmarks.bench_perfbound"),           # Figs 8/11/14/17
    ("decoupled", "benchmarks.bench_decoupled"),           # DESIGN.md §3
    ("kernels", "benchmarks.bench_kernels"),               # kernel parity
    ("llm_traffic", "benchmarks.bench_llm_traffic"),       # beyond paper
    ("topology", "benchmarks.bench_topology"),             # beyond paper
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "paper"], default="small")
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys to run")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import importlib
    print("name,us_per_call,derived")
    failures = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            for row in mod.run(args.scale):
                print(row.csv(), flush=True)
        except Exception as e:  # keep the suite going; report at the end
            failures.append((key, repr(e)))
            print(f"{key}/ERROR,0.0,{e!r}", flush=True)
        print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        print(f"# {len(failures)} module(s) failed: {failures}")
        sys.exit(1)
    print("# all benchmark modules passed")


if __name__ == "__main__":
    main()
