"""Paper Figs 6/9/12/15: network-efficiency timelines per application.

Network efficiency = bytes on the wire per time bin / (total network
bandwidth x bin).  We report peak and mean efficiency and the fraction of
bins with any traffic — the signature of each app's timeline:
LAMMPS intermittent spikes after ~1 s setup; PATMOS endpoint-only; MLWF
near-continuous; AlexNet periodic bursts.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PM, Row, get_apps, get_topo, timed
from repro.core import simulator as S
from repro.core.eee import Policy


def efficiency_timeline(topo, trace, n_bins=200):
    res, events = S.simulate_trace(trace, topo, Policy(kind="none"), PM,
                                   collect_events=True)
    t_end = res.makespan
    busy_bytes = np.zeros(n_bins)
    for lp, ts, te in events:
        b = np.clip((ts / t_end * n_bins).astype(int), 0, n_bins - 1)
        np.add.at(busy_bytes, b, (te - ts) * PM.link_bandwidth)
    cap = topo.n_links * 2 * PM.link_bandwidth * (t_end / n_bins)
    eff = busy_bytes / cap
    return eff, res


def run(scale: str = "small"):
    topo = get_topo(scale)
    rows = []
    for name, trace in get_apps(scale, topo).items():
        (eff, res), us = timed(efficiency_timeline, topo, trace)
        active = float((eff > 0).mean())
        rows.append(Row(
            f"traffic/{name}", us,
            f"peak_eff={eff.max():.4f} mean_eff={eff.mean():.2e} "
            f"active_bins={active:.2f} total_GB={trace.total_bytes/2**30:.2f} "
            f"makespan={res.makespan:.3g}s"))
    return rows
