"""Streaming advisor throughput: the closed-loop windowed pipeline.

Measures what the online advisor costs per window on top of a plain suite
sweep: wall time for the full stream (pool seeding excluded — the tuner is
benchmarked by ``bench_tuner``), windows/s, the switch count, and the
stream's compile trajectory.  The warm-path contract is the headline
number: within a stream only window 0 compiles, and the warm pass of the
``BENCH_stream.json`` record (same drifts, resident window/plan caches)
must compile ZERO programs — ``check_compiles.py`` guards that against
``baselines/compile_counts.json`` ("stream": 0) in the stream-smoke CI
job.

Scales:
  * tiny  — regimes + diurnal drifts, 6 windows x 8-node allocations on
    the 12-node Megafly, fixed 3-candidate pool (CI smoke).
  * small — all three catalog drifts, 12 windows x 16 nodes on the
    80-node Megafly.
  * paper — the catalog drifts at their full 24 windows, 64-node
    allocations on the 4160-node Megafly.
"""
from __future__ import annotations

from benchmarks.common import PM, Row, get_topo, timed
from repro.core.eee import Policy
from repro.streaming import advise_stream, get_drift

# A fixed pool keeps the bench focused on the windowed pipeline (and its
# compile counts deterministic): one aggressive deep sleeper, one mild
# fast-waker, one two-stage policy — the regimes the drift catalog flips
# between.
POOL = {
    "fixed-ds-1us": Policy(kind="fixed", t_pdt=1e-6,
                           sleep_state="deep_sleep"),
    "fixed-fw-100us": Policy(kind="fixed", t_pdt=1e-4,
                             sleep_state="fast_wake"),
    "dual-10us-200us": Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                              sleep_state="fast_wake",
                              deep_state="deep_sleep"),
}


def _setup(scale: str):
    """(drifts, n_nodes, windows, budget_pct) per scale.

    The budget tightens with scale: the aggressive sleeper's per-window
    overhead shrinks on bigger topologies (more links amortize each wake),
    so the budget that separates quiet-feasible from busy-infeasible —
    the inversion the bench showcases — moves down (0.1 on the 12-node
    tiny Megafly, 0.06 on the 80-node small one; see DESIGN.md §11)."""
    if scale == "tiny":
        return ["drift-dc-regimes", "drift-dc-diurnal"], 8, 6, 0.1
    if scale == "paper":
        return ["drift-dc-regimes", "drift-dc-diurnal",
                "drift-dc-flash"], 64, None, 0.06
    return (["drift-dc-regimes", "drift-dc-diurnal", "drift-dc-flash"],
            16, 12, 0.06)


def n_policies(scale: str) -> int:
    return len(POOL)


def run(scale: str):
    topo = get_topo(scale)
    names, n_nodes, windows, budget = _setup(scale)
    rows = []
    for name in names:
        spec = get_drift(name).scaled(n_nodes=n_nodes, windows=windows)
        out, us = timed(advise_stream, spec, topo, pool=POOL,
                        budget_pct=budget, pm=PM)
        compiles = [r["compiles"] for r in out["timeline"]]
        t = out["totals"]
        rows.append(Row(
            f"stream/{name}", us,
            f"{spec.windows}w_{spec.windows / (us / 1e6):.2f}w_per_s_"
            f"switches{out['switches']}_"
            f"onlinesaved{t['online_saved_pct']:.2f}pct_"
            f"staticsaved{t['best_static_saved_pct']:.2f}pct_"
            f"gain{t['gain_vs_static_pct']:.2f}pct_"
            f"compiles{compiles[0]}-then-{max(compiles[1:], default=0)}"))
    return rows
