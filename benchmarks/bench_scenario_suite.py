"""Scenario-suite throughput: the (scenario x policy) grid on the
multi-trace batched replay path (beyond paper).

Measures what the new axis buys: wall time for a whole catalog sweep and
the per-cell rate, with plan stacking (``traffic.plan.stack_plans``)
collapsing same-shape scenarios into shared compiled programs.  The
``BENCH_scenario_suite.json`` record starts the multi-trace perf
trajectory: ``rows`` carry cells/s, and warm passes exercise the trace /
plan / program caches end to end.

Scales:
  * tiny  — the 4-scenario dc-* family (one stack) x 5 policies (one per
    FSM family, incl. the predictive precoalesce/predict kinds), 8-node
    allocations on the 12-node Megafly: the CI smoke grid.
  * small — 8 scenarios across all four families x the default 9-policy
    grid on the 80-node Megafly.
  * paper — the full catalog at 64-node allocations on the 4160-node
    Megafly.
"""
from __future__ import annotations

from benchmarks.common import PM, Row, get_topo, timed
from repro import scenarios as SC
from repro.core.eee import Policy
from repro.core.sweep import group_policies


def _grid(scale: str) -> dict:
    if scale == "tiny":
        return {
            "fixed-ds-100us": Policy(kind="fixed", t_pdt=1e-4,
                                     sleep_state="deep_sleep"),
            "perfbound-1pct": Policy(kind="perfbound", bound=0.01),
            "dual-10us-200us": Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                                      sleep_state="fast_wake",
                                      deep_state="deep_sleep"),
            "precoalesce-50us": Policy(kind="precoalesce", t_pdt=1e-5,
                                       t_dst=2e-4, hold_delay=5e-5,
                                       hold_frames=16,
                                       sleep_state="fast_wake",
                                       deep_state="deep_sleep"),
            "predict-ewma": Policy(kind="predict", t_pdt=1e-5, t_dst=2e-4,
                                   forecast_weight=0.5, forecast_margin=2.0,
                                   sleep_state="fast_wake",
                                   deep_state="deep_sleep"),
        }
    return SC.default_policy_grid()


def _scenarios(scale: str) -> tuple:
    if scale == "tiny":
        return ["dc-poisson", "dc-hotspot", "dc-onoff", "dc-incast"], 8
    if scale == "paper":
        return SC.list_scenarios(), 64
    return ["ml-qwen2-1.5b", "ml-gemma3-4b", "hpc-stencil3d",
            "hpc-spectral", "dc-poisson", "dc-onoff", "dc-incast",
            "app-lammps"], None


def n_policies(scale: str) -> int:
    return len(_grid(scale))


def run(scale: str):
    topo = get_topo(scale)
    names, n_nodes = _scenarios(scale)
    grid = _grid(scale)
    res, us = timed(SC.run_suite, topo, scenarios=names, policies=grid,
                    pm=PM, n_nodes=n_nodes)
    cells = len(names) * (len(grid) + 1)          # baseline lane rides along
    rows = [Row("suite/grid", us,
                f"{len(names)}x{len(grid) + 1}cells_"
                f"{len(group_policies(grid))}groups_"
                f"{cells / (us / 1e6):.2f}cells_per_s")]
    for sc, pols in res.items():
        best = min((p for p in pols if p != "baseline"),
                   key=lambda p: pols[p]["total_energy"])
        rows.append(Row(
            f"suite/{sc}", us / len(names),
            f"best={best}_saved{pols[best]['energy_saved_pct']:.2f}pct_"
            f"ovh{pols[best]['exec_overhead_pct']:.2f}pct"))
    return rows
