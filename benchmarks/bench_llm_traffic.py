"""Beyond-paper: EEE power management under REAL LLM training/serving
traffic — the collective schedule extracted from this framework's own
compiled (dry-run) cells, replayed on the paper's 4160-node Megafly.

This realizes the paper's motivation ('AI workloads ... can also benefit
from this topology') with measured, not synthetic, traffic.  Uses cells
already produced by ``python -m repro.launch.dryrun``; skips cleanly if a
cell JSON is missing.
"""
from __future__ import annotations

from benchmarks.common import PM, Row, timed
from repro.launch import power_advisor as PA

CELLS = [("qwen2-1.5b", "train_4k"), ("qwen3-moe-30b-a3b", "train_4k"),
         ("qwen2-1.5b", "decode_32k")]


def run(scale: str = "small"):
    rows = []
    n_steps = 3 if scale == "paper" else 2
    for arch, shape in CELLS:
        try:
            out, us = timed(PA.advise, arch, shape, n_steps=n_steps)
        except (FileNotFoundError, ValueError) as e:
            rows.append(Row(f"llm/{arch}/{shape}", 0.0, f"skipped: {e}"))
            continue
        tp, dp = out["tp_dp_bytes"]
        for name, r in out["table"].items():
            if name == "baseline":
                continue
            rows.append(Row(
                f"llm/{arch}/{shape}/{name}", us / len(out["table"]),
                f"exec_oh={r['exec_overhead_pct']:.3f}% "
                f"saved={r['energy_saved_pct']:.2f}% "
                f"link_saved={r['link_energy_saved_pct']:.2f}%"))
        rows.append(Row(
            f"llm/{arch}/{shape}/summary", us,
            f"TP={tp/2**20:.1f}MiB/dev/step DP={dp/2**20:.1f}MiB "
            f"recommended={out['recommended']}"))
    return rows
