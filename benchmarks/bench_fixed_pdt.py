"""Paper Figs 7/10/13/16: fixed t_PDT sweep — execution-time overhead,
energy saved, packet-latency overhead, per app x sleep state x t_PDT.

The 9-point t_PDT grid runs on the COUPLED simulator (exact §4 protocol):
overheads feed back into timing, as in the paper.  All fixed-t_PDT policies
share one static structure, so the entire grid (both sleep states) replays
each trace ONCE through the batched sweep engine — one compiled scan per
chunk with a policy-batch axis — instead of once per grid point.

Qualitative targets (§4.1.1): Deep Sleep with t_PDT <= 10 µs more than
doubles LAMMPS runtime while Fast Wake stays < 10 %; savings ~10 % at
t_PDT >= 100 µs; fixed t_PDT >= 1 ms barely saves anything.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (PM, Row, SLEEP_STATES, TPDT_GRID, get_apps,
                               get_topo, timed)
from repro.core.eee import Policy
from repro.core.simulator import compare_policies


def _grid(scale: str):
    if scale == "paper":
        return TPDT_GRID
    if scale == "tiny":
        return [0.0, 1e-5, 1e-3]
    return TPDT_GRID[::2] + [1.0]


def n_policies(scale: str = "small") -> int:
    return len(SLEEP_STATES) * len(_grid(scale))


def run(scale: str = "small"):
    topo = get_topo(scale)
    grid = _grid(scale)
    rows = []
    for name, trace in get_apps(scale, topo).items():
        pols = {f"{st}/t={t:g}": Policy(kind="fixed", t_pdt=t,
                                        sleep_state=st)
                for st in SLEEP_STATES for t in grid}
        out, us = timed(compare_policies, trace, topo, pols, PM)
        for key, r in out.items():
            if key == "baseline":
                continue
            rows.append(Row(
                f"fixed_pdt/{name}/{key}", us / max(len(pols), 1),
                f"exec_oh={r['exec_overhead_pct']:.2f}% "
                f"lat_oh={r['latency_overhead_pct']:.2f}% "
                f"saved={r['energy_saved_pct']:.2f}% "
                f"link_saved={r['link_energy_saved_pct']:.2f}% "
                f"wakes={r['n_wake_transitions']}"))
    return rows
