"""Shared benchmark scaffolding.

Every bench module exposes ``run(scale) -> list[Row]``.  ``scale``:
  * ``tiny``   — minimal topology (12 nodes) + 2 shortest app traces; the
    CI benchmark-smoke scale (seconds, still exercises the full compiled
    replay pipeline).
  * ``small``  — reduced topology (80 nodes) + shortened app traces; the
    default for ``python -m benchmarks.run`` so the suite finishes on CPU
    in minutes.
  * ``paper``  — the full §4 scenario (4160-node Megafly, 64-node apps).
    Same code path, hours on CPU; numbers quoted in EXPERIMENTS.md
    §Paper-validation were produced at this scale where noted.

Rows print as ``name,us_per_call,derived`` CSV (one per measured quantity).
Modules may additionally expose ``n_policies(scale) -> int`` so the driver
can record grid sizes in the ``BENCH_<name>.json`` perf-trajectory files.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass

import numpy as np

from repro.core.eee import Policy, PowerModel
from repro.topology.megafly import paper_topology, small_topology
from repro.traffic import generators as G


@dataclass
class Row:
    name: str
    us_per_call: float        # wall time of the measured computation
    derived: str              # the quantity the paper's figure/table shows

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def get_topo(scale: str):
    if scale == "paper":
        return paper_topology()
    if scale == "tiny":
        return small_topology(n_groups=3, leaves=2, spines=2,
                              nodes_per_leaf=2)
    return small_topology()


def get_apps(scale: str, topo):
    if scale == "paper":
        return {
            "lammps": G.lammps(topo, n_nodes=64, iters=40),
            "patmos": G.patmos(topo, n_nodes=64, compute_secs=1285.0),
            "mlwf": G.mlwf(topo, n_nodes=64, steps=25, layers=8),
            "alexnet": G.alexnet(topo, n_nodes=64, iters=10),
        }
    if scale == "tiny":
        return {
            "lammps": G.lammps(topo, n_nodes=8, iters=2),
            "alexnet": G.alexnet(topo, n_nodes=8, iters=1),
        }
    return {
        "lammps": G.lammps(topo, n_nodes=16, iters=10),
        "patmos": G.patmos(topo, n_nodes=16, compute_secs=30.0),
        "mlwf": G.mlwf(topo, n_nodes=16, steps=5, layers=4),
        "alexnet": G.alexnet(topo, n_nodes=16, iters=3),
    }


# The paper's evaluation grid (§4): 9 fixed t_PDT values 0 .. 1 s,
# 3 PerfBound thresholds, 3 histogram modes, 2 sleep states.
TPDT_GRID = [0.0, 1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0]
BOUNDS = [0.01, 0.02, 0.05]
HIST_MODES = ["keep_all", "self_clear", "circular"]
SLEEP_STATES = ["fast_wake", "deep_sleep"]

PM = PowerModel()
