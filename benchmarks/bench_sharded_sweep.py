"""Sharded multi-device sweep engine vs the single-device baseline.

Three lanes of the SAME dense tuner pass (``tune_scenarios`` over the
dc-* stack — the PR-5/6 search riding ``sweep_cells`` end to end), so
the speedup attribution is honest:

  * ``1dev-pow2``   — the single-device engine on power-of-two plans
    (the pre-existing production path; the baseline row);
  * ``1dev-ragged`` — same device, ragged/size-class plans
    (``plan.repack_plans``): the padded-slot reduction is pure
    inner-scan work removed, so this isolates the memory-audit win;
  * ``Ndev-ragged`` — ragged plans sharded over every visible device
    (``distributed.shard_sweep``): adds the mesh win on top.  On a
    single-core host with forced host-platform devices this lane is
    expected to be ~flat (XLA host devices share the one core — the
    mesh win needs real parallel hardware); CI runs it for the compile
    and bit-identity contracts, not local speedup.

Every lane reports wall time and its speedup vs ``1dev-pow2``; the warm
pass of ``BENCH_sharded_sweep.json`` must compile ZERO programs
(``check_compiles.py`` guards ``baselines/compile_counts.json``).

Scales:
  * tiny  — 4 dc-* scenarios x the 12-candidate ``tiny_space``, 2
    rounds, 8-node allocations on the 12-node Megafly (CI smoke).
  * small — dc-* + hpc-* x ``default_space``, 3 rounds, 80-node Megafly.
  * paper — the whole catalog at 64-node allocations, 4160-node Megafly.
"""
from __future__ import annotations

import jax

from benchmarks.common import PM, Row, get_topo, timed
from repro import tuning
from repro.distributed import shard_sweep


def _setup(scale: str):
    if scale == "tiny":
        return (["dc-poisson", "dc-hotspot", "dc-onoff", "dc-incast"], 8,
                tuning.tiny_space(), 2)
    if scale == "paper":
        return None, 64, tuning.default_space(), 3
    return (["dc-poisson", "dc-hotspot", "dc-onoff", "dc-incast",
             "hpc-stencil3d", "hpc-stencil2d", "hpc-spectral"], None,
            tuning.default_space(), 3)


def n_policies(scale: str) -> int:
    return len(tuning.space_candidates(_setup(scale)[2])[0])


def _tune(topo, names, n_nodes, space, rounds, packing):
    return tuning.tune_scenarios(
        topo, names, budget_pct=1.0, rounds=rounds, space=space,
        keep=3, n_nodes=n_nodes, pm=PM, packing=packing)


def run(scale: str):
    topo = get_topo(scale)
    names, n_nodes, space, rounds = _setup(scale)
    n_dev = jax.device_count()

    report, us_pow2 = timed(_tune, topo, names, n_nodes, space, rounds,
                            "pow2")
    cells = sum(r["cells"] for r in report.rounds)
    rows = [Row("sharded_sweep/1dev-pow2", us_pow2,
                f"{len(report.scenarios)}scen_{cells}cells_"
                f"{cells / (us_pow2 / 1e6):.2f}cells_per_s_speedup1.00x")]

    ragged, us_ragged = timed(_tune, topo, names, n_nodes, space, rounds,
                              "ragged")
    rows.append(Row("sharded_sweep/1dev-ragged", us_ragged,
                    f"{cells / (us_ragged / 1e6):.2f}cells_per_s_"
                    f"speedup{us_pow2 / us_ragged:.2f}x"))

    with shard_sweep.use_mesh():
        sharded, us_mesh = timed(_tune, topo, names, n_nodes, space,
                                 rounds, "ragged")
    rows.append(Row("sharded_sweep/Ndev-ragged", us_mesh,
                    f"{n_dev}dev_{cells / (us_mesh / 1e6):.2f}cells_per_s_"
                    f"speedup{us_pow2 / us_mesh:.2f}x"))

    # the contract rows: all three lanes must land on identical winners
    for sc, t in report.scenarios.items():
        for other in (ragged, sharded):
            o = other.scenarios[sc]
            assert o.winner.name == t.winner.name, \
                (sc, o.winner.name, t.winner.name)
            assert o.winner.row == t.winner.row, sc
        rows.append(Row(
            f"sharded_sweep/{sc}", us_mesh / len(report.scenarios),
            f"winner={t.winner.name}_identical_across_lanes"))
    return rows
