"""Kernel microbenchmarks: Pallas (interpret mode on CPU — the TPU program
is identical) vs the pure-jnp oracle, on paper-scale port counts (20800
directed port-ends), plus oracle-parity checks."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PM, Row, timed
from repro.core.eee import DEEP_SLEEP
from repro.kernels import ops


def run(scale: str = "small"):
    rng = np.random.default_rng(0)
    P = 20800 if scale == "paper" else 2048
    E, B = 512, 200
    gaps = rng.uniform(0, 2e-3, (E, P)).astype(np.float32)
    durs = rng.uniform(0, 1e-4, (E, P)).astype(np.float32)
    tpdt = rng.uniform(0, 1e-3, (P,)).astype(np.float32)
    tail = rng.uniform(0, 1.0, (P,)).astype(np.float32)
    counts = rng.integers(0, 20, (P, B)).astype(np.float32)
    centers = ((np.arange(B) + 0.5) * 1e-5).astype(np.float32)
    sums = counts * centers[None]
    N = rng.uniform(0, 50, (P,)).astype(np.float32)
    total = counts.sum(1)

    rows = []

    def bench(name, fn, *args, check=None, **kw):
        out, _ = timed(fn, *args, **kw)          # compile
        outs, us = [], []
        for _ in range(3):
            out, u = timed(fn, *args, **kw)
            us.append(u)
        parity = ""
        if check is not None:
            ref = fn(*args, **kw, use_ref=True)
            err = check(out, ref)
            parity = f" max_err={err:.2e}"
        rows.append(Row(f"kernels/{name}", float(np.median(us)),
                        f"P={P}{parity}"))
        return out

    def arr_err(a, b):
        return float(np.max(np.abs(np.asarray(a) - np.asarray(b))))

    def dict_err(a, b):
        return max(arr_err(a[k], b[k]) for k in a)

    def pair_err(a, b):
        return max(arr_err(a[0], b[0]), arr_err(a[1], b[1]))

    bench("tpdt_select", lambda *a, **k: ops.tpdt_select_op(*a, **k),
          counts, sums, N, total, centers,
          max_tpdt=10e-3, tpdt_init=1e-3, check=arr_err)
    bench("hist_update", lambda *a, **k: ops.hist_update_op(*a, **k),
          gaps, n_bins=B, bin_width=10e-6, check=pair_err)
    bench("port_energy", lambda *a, **k: ops.port_energy_op(*a, **k),
          gaps, durs, tpdt, tail, t_w=DEEP_SLEEP.t_w, t_s=DEEP_SLEEP.t_s,
          check=dict_err)

    # model-side kernels (reduced shapes; TPU program identical)
    q = rng.normal(size=(2, 256, 8, 64)).astype(np.float32)
    k = rng.normal(size=(2, 256, 2, 64)).astype(np.float32)
    v = rng.normal(size=(2, 256, 2, 64)).astype(np.float32)
    bench("flash_attn_fwd", lambda *a, **kw: ops.flash_attention_op(*a, **kw),
          q, k, v, causal=True, block_q=64, block_kv=64, check=arr_err)
    xs = rng.normal(size=(2, 256, 4, 32)).astype(np.float32)
    dts = rng.uniform(0.001, 0.1, (2, 256, 4)).astype(np.float32)
    Bc = rng.normal(size=(2, 256, 16)).astype(np.float32)
    Cc = rng.normal(size=(2, 256, 16)).astype(np.float32)
    A = (-rng.uniform(0.5, 4.0, 4)).astype(np.float32)
    Dp = rng.normal(size=4).astype(np.float32)
    bench("ssd_fwd", lambda *a, **kw: ops.ssd_op(*a, **kw),
          xs, dts, Bc, Cc, A, Dp, chunk=64, check=pair_err)
    return rows
