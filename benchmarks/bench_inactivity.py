"""Paper Fig 1: per-port inactivity-period histograms for each application.

For one representative busy port per app we report the count of inactivity
periods, the p50/p99 gap lengths, and the fraction of periods below 1 ms —
the quantities Fig 1's histograms/CDFs encode.  The paper's qualitative
claims validated here: AlexNet ~90 % of gaps in the sub-µs..ns decade
(§4.4.1); MLWF 99 % within the millisecond range (§4.3.1); PATMOS has few,
enormous gaps (§4.2).

A second row per app closes the loop from Fig 1 to policy choice: a dense
fixed-t_PDT grid runs through the batched sweep engine (one coupled replay
for the whole grid) and reports the energy-optimal t_PDT — which should
land just above the app's gap distribution knee.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PM, Row, get_apps, get_topo, timed
from repro.core import decoupled as D
from repro.core import simulator as S
from repro.core.eee import Policy
from repro.core.sweep import sweep_policies


def port_gap_stats(topo, trace):
    res, events = S.simulate_trace(trace, topo, Policy(kind="none"), PM,
                                   collect_events=True)
    gaps, durs, tail = D.events_to_streams(events, topo.n_links,
                                           res.makespan)
    g, d = np.asarray(gaps), np.asarray(durs)
    busy = np.argsort(-(d > 0).sum(0))
    port = int(busy[0])                     # the busiest port
    pg = g[:, port][d[:, port] > 0]
    pg = pg[pg > 0]
    return port, pg, res


def run(scale: str = "small"):
    topo = get_topo(scale)
    rows = []
    for name, trace in get_apps(scale, topo).items():
        (port, pg, res), us = timed(port_gap_stats, topo, trace)
        if len(pg) == 0:
            rows.append(Row(f"fig1/{name}", us, "no gaps"))
            continue
        p50, p99 = np.percentile(pg, [50, 99])
        sub_ms = float((pg < 1e-3).mean())
        rows.append(Row(
            f"fig1/{name}", us,
            f"port={port} n_gaps={len(pg)} p50={p50:.3g}s p99={p99:.3g}s "
            f"frac<1ms={sub_ms:.2f} makespan={res.makespan:.3g}s"))
        # Fig 1 -> policy choice: the whole t_PDT curve in ONE batched
        # replay (all fixed policies share static structure)
        grid = {f"t={t:g}": Policy(kind="fixed", t_pdt=t,
                                   sleep_state="deep_sleep")
                for t in np.geomspace(1e-7, 1e-1, 13)}
        swept, us_grid = timed(sweep_policies, trace, topo, grid, PM)
        best = min(swept, key=lambda k: swept[k].link_energy)
        rows.append(Row(
            f"fig1/{name}/tpdt_curve", us_grid / len(grid),
            f"best_{best} link_e={swept[best].link_energy:.4g}J "
            f"asleep={swept[best].asleep_frac:.2f} "
            f"grid={len(grid)}pts_1_replay"))
    return rows
