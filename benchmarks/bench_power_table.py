"""Paper Tables 5/6: system power inventory and the network's share of
total power per link state, on the exact 4160-node scenario.

Validation targets (Table 6): Wake 18.575 % / 13.201 % (network/total,
idle vs full load), Fast Wake 12.136 % / 8.432 %, Deep Sleep
8.519 % / 5.845 %; links/network idle 12.214 % / 5.272 % / 1.372 %.
"""
from __future__ import annotations

from benchmarks.common import PM, Row, timed
from repro.topology.megafly import paper_topology

# (state, net/total idle %, net/total full %, links/total idle %)
PAPER_TABLE6 = {
    "wake": (18.575, 13.201, 12.214),
    "fast_wake": (12.136, 8.432, 5.272),
    "deep_sleep": (8.519, 5.845, 1.372),
}


def run(scale: str = "small"):
    topo = paper_topology()           # the table is topology-exact; cheap
    table, us = timed(PM.static_table, topo)
    rows = []
    for state, t in table.items():
        got = (100 * t["network_of_total_idle"],
               100 * t["network_of_total_full"],
               100 * t["links_of_total_idle"])
        want = PAPER_TABLE6[state]
        err = max(abs(g - w) for g, w in zip(got, want))
        rows.append(Row(
            f"table6/{state}", us,
            f"net/total idle={got[0]:.3f}% full={got[1]:.3f}% "
            f"links idle={got[2]:.3f}% paper=({want[0]}/{want[1]}/{want[2]}) "
            f"max_err={err:.3f}pp"))
    # Table 5 absolutes
    rows.append(Row(
        "table5/inventory", us,
        f"switches={topo.n_switches} nodes={topo.n_nodes} "
        f"ports={topo.n_ports} links_max_kW={PM.port_power*topo.n_ports/1e3:.1f} "
        f"(paper 499.2 kW... per-port x 20800)"))
    return rows
