"""End-to-end training driver: train a ~100M-parameter qwen2-family model
on the synthetic Markov LM for a few hundred steps on whatever devices
exist, with checkpoint/restart in the middle to prove the recovery path.

The Markov stream has log2(4) bits/token of irreducible entropy; the run
asserts the loss drops materially from its ln(vocab) starting point toward
that floor, and that a mid-run restart reproduces the exact loss curve.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--tiny]
"""
import argparse
import dataclasses
import shutil
import tempfile

import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.train import train


def model_100m():
    """~100M params, qwen2-style (GQA + SwiGLU + RMSNorm)."""
    return ModelConfig(
        name="qwen2-100m", family="dense", num_layers=8, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32768, qkv_bias=True, dtype="float32",
        attn_direct_max_seq=512)


def model_tiny():
    return dataclasses.replace(
        model_100m(), name="qwen2-tiny", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512,
        vocab_pad_multiple=8)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--tiny", action="store_true",
                    help="2-layer model (CI-speed)")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    import jax
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(
        jax.eval_shape(lambda: __import__("repro.models.model",
                                          fromlist=["init_params"])
                       .init_params(cfg, jax.random.PRNGKey(0)))))
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    ckpt = tempfile.mkdtemp(prefix="repro_e2e_")
    try:
        half = args.steps // 2
        _, losses1 = train(cfg, steps=half, seq_len=args.seq_len,
                           global_batch=args.global_batch, lr=args.lr,
                           ckpt_dir=ckpt, save_every=half, log_every=20)
        print(f"--- simulated preemption at step {half}; restarting ---")
        _, losses2 = train(cfg, steps=args.steps, seq_len=args.seq_len,
                           global_batch=args.global_batch, lr=args.lr,
                           ckpt_dir=ckpt, save_every=10**9, resume=True,
                           log_every=20)
        losses = losses1 + losses2
        first = float(np.mean(losses[:5]))
        last = float(np.mean(losses[-10:]))
        floor = np.log(4)
        print(f"\nloss: {first:.3f} (start, ln V={np.log(cfg.vocab_size):.2f})"
              f" -> {last:.3f} (floor ln 4 = {floor:.3f})")
        assert last < first - 0.5, "loss did not decrease by 0.5 nats"
        print("OK: learned the Markov structure; checkpoint restart worked")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
