"""Batched serving: prefill a batch of prompts, then greedy-decode with the
family-appropriate cache (KV / Mamba2 state / RWKV state), for any of the
10 assigned architectures (reduced config on CPU).

Run:  PYTHONPATH=src python examples/serve_batched.py \\
          [--arch zamba2-7b] [--batch 4] [--prompt-len 16] [--steps 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, list_archs
from repro.models import model as M
from repro.serving.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-7b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    print(f"arch: {args.arch} (reduced config: {cfg.num_layers}L "
          f"d={cfg.d_model}, family={cfg.family})")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    t0 = time.perf_counter()
    toks = generate(params, cfg, prompts, steps=args.steps)
    dt = time.perf_counter() - t0
    n_new = args.batch * args.steps
    print(f"generated {n_new} tokens in {dt:.2f}s "
          f"({n_new/dt:.1f} tok/s incl. compile)")
    for b in range(args.batch):
        print(f"  req{b}: prompt={np.asarray(prompts[b][:8]).tolist()}... "
              f"-> {np.asarray(toks[b]).tolist()}")


if __name__ == "__main__":
    main()
