"""Power advisor: evaluate EEE link power-management for a compiled LLM
training job BEFORE it runs — the framework's first-class integration of
the paper's technique (DESIGN.md §2 Layer B).

Reads the multi-pod dry-run artifact for an (arch x shape) cell (compiled
collective schedule + FLOPs), replays it as traffic on the paper's
4160-node Megafly, and recommends the best policy under an overhead bound.

Run:  PYTHONPATH=src python examples/power_advisor.py \\
          [--arch qwen2-1.5b] [--shape train_4k] [--max-overhead-pct 1.0]
(requires experiments/dryrun JSONs — `python -m repro.launch.dryrun --all`)
"""
import argparse

from repro.launch.power_advisor import advise


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--max-overhead-pct", type=float, default=1.0)
    args = ap.parse_args()

    out = advise(args.arch, args.shape, args.mesh, n_steps=args.steps,
                 max_overhead_pct=args.max_overhead_pct)
    c = out["cell"]
    tp, dp = out["tp_dp_bytes"]
    print(f"job: {c['arch']} / {c['shape']} on {c['mesh']} "
          f"({c['n_devices']} chips mapped onto the 4160-node Megafly)")
    print(f"measured collective schedule: TP/EP {tp/2**20:.1f} MiB per "
          f"device-step, DP {dp/2**20:.1f} MiB")
    hdr = (f"{'policy':18s} {'exec_oh%':>9s} {'lat_oh%':>9s} "
           f"{'saved%':>8s} {'link_saved%':>12s}")
    print("\n" + hdr + "\n" + "-" * len(hdr))
    for name, r in out["table"].items():
        print(f"{name:18s} {r['exec_overhead_pct']:9.3f} "
              f"{r['latency_overhead_pct']:9.2f} "
              f"{r['energy_saved_pct']:8.2f} "
              f"{r['link_energy_saved_pct']:12.2f}")
    print(f"\nrecommended (overhead <= {args.max_overhead_pct}%): "
          f"{out['recommended']}")


if __name__ == "__main__":
    main()
