"""Quickstart: simulate EEE power management on an HPC application trace.

Builds the paper's 4160-node Megafly, generates a LAMMPS-like trace, and
compares the paper's policies — fixed-PDT, PerfBound, and the paper's
contribution PerfBoundCorrect — printing the §4 metrics (execution-time
overhead, packet-latency overhead, energy saved).

Run:  PYTHONPATH=src python examples/quickstart.py [--small]
"""
import argparse

from repro.core.eee import Policy, PowerModel
from repro.core.simulator import compare_policies
from repro.topology.megafly import paper_topology, small_topology
from repro.traffic.generators import lammps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="80-node topology + short trace (seconds, not minutes)")
    args = ap.parse_args()

    topo = small_topology() if args.small else paper_topology()
    trace = lammps(topo, n_nodes=16 if args.small else 64,
                   iters=8 if args.small else 40)
    print(f"topology: {topo.n_nodes} nodes, {topo.n_switches} switches, "
          f"{topo.n_ports} port-ends")
    print(f"trace: {trace.name}, {trace.n_messages} messages, "
          f"{trace.total_bytes / 2**30:.2f} GiB")

    policies = {
        "fixed_fw_100us": Policy(kind="fixed", t_pdt=100e-6,
                                 sleep_state="fast_wake"),
        "fixed_ds_100us": Policy(kind="fixed", t_pdt=100e-6,
                                 sleep_state="deep_sleep"),
        "perfbound_1pct": Policy(kind="perfbound", bound=0.01,
                                 sleep_state="deep_sleep"),
        "pbc_1pct": Policy(kind="perfbound_correct", bound=0.01,
                           sleep_state="deep_sleep"),
    }
    table = compare_policies(trace, topo, policies, PowerModel())

    hdr = (f"{'policy':18s} {'exec_oh%':>9s} {'lat_oh%':>9s} "
           f"{'saved%':>8s} {'link_saved%':>12s} {'asleep':>7s}")
    print("\n" + hdr + "\n" + "-" * len(hdr))
    for name, r in table.items():
        print(f"{name:18s} {r['exec_overhead_pct']:9.3f} "
              f"{r['latency_overhead_pct']:9.2f} "
              f"{r['energy_saved_pct']:8.2f} "
              f"{r['link_energy_saved_pct']:12.2f} "
              f"{r['asleep_frac']:7.2f}")
    pbc, pb = table["pbc_1pct"], table["perfbound_1pct"]
    print(f"\nPerfBoundCorrect vs PerfBound: latency overhead "
          f"{pb['latency_overhead_pct']:.2f}% -> "
          f"{pbc['latency_overhead_pct']:.2f}%, energy saved "
          f"{pb['energy_saved_pct']:.2f}% -> {pbc['energy_saved_pct']:.2f}%")


if __name__ == "__main__":
    main()
