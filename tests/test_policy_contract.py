"""The Policy static/param factoring contract (DESIGN.md §6), LinkState
validation, predictor-state gating, and the new-kind compile-count pin."""
import dataclasses

import numpy as np
import pytest

from repro.core import perfbound as pb
from repro.core.eee import (PARAM_FIELDS, STATIC_FIELDS, _LOWERED_FIELDS,
                            _STATE_TABLE_FIELDS, EEE_STATES, LinkState,
                            Policy, canonical_proto, policy_params,
                            static_key)
from repro.core.instrument import count_compiles
from repro.core.sweep import group_policies, sweep_policies
from repro.traffic.trace import Trace

ALL_KINDS = ("none", "fixed", "perfbound", "perfbound_correct",
             "dual", "coalesce", "perfbound_dual", "precoalesce", "predict")
SINGLE_KINDS = ("none", "fixed", "perfbound", "perfbound_correct")
DUAL_KINDS = ("dual", "coalesce", "perfbound_dual", "precoalesce",
              "predict")


def _policy(kind):
    kw = {}
    if kind in DUAL_KINDS:
        kw = dict(sleep_state="fast_wake", deep_state="deep_sleep",
                  t_dst=2e-4)
    if kind == "coalesce":
        kw.update(max_delay=5e-5, max_frames=8)
    if kind == "precoalesce":
        kw.update(hold_delay=5e-5, hold_frames=8)
    if kind == "predict":
        kw.update(forecast_weight=0.5, forecast_margin=2.0)
    return Policy(kind=kind, t_pdt=1e-5, **kw)


# ---------------------------------------------------------------------------
# LinkState validation (a true off state is representable)
# ---------------------------------------------------------------------------


def test_linkstate_allows_power_off():
    off = LinkState("off", t_w=1e-3, t_s=1e-4, power_frac=0.0)
    assert off.power_frac == 0.0


@pytest.mark.parametrize("kw", [
    dict(t_w=0.0, t_s=1e-6, power_frac=0.1),     # instant wake
    dict(t_w=1e-6, t_s=0.0, power_frac=0.1),     # instant down
    dict(t_w=1e-6, t_s=1e-6, power_frac=-0.1),   # negative power
    dict(t_w=1e-6, t_s=1e-6, power_frac=1.0),    # no saving at all
])
def test_linkstate_rejects_invalid(kw):
    with pytest.raises(AssertionError):
        LinkState("bad", **kw)


def test_dual_policy_validation():
    # inverted ladder: deep row must not wake faster / burn more
    with pytest.raises(AssertionError):
        Policy(kind="dual", sleep_state="deep_sleep",
               deep_state="fast_wake")
    with pytest.raises(AssertionError):
        Policy(kind="dual", t_dst=-1.0)
    with pytest.raises(AssertionError):
        Policy(kind="coalesce", max_delay=-1e-6)
    with pytest.raises(AssertionError):
        Policy(kind="coalesce", max_frames=0)


# ---------------------------------------------------------------------------
# Field classification: every Policy field is param, static, or state-table
# ---------------------------------------------------------------------------


def test_every_field_is_classified():
    classified = (set(PARAM_FIELDS) - set(_STATE_TABLE_FIELDS)) \
        | set(STATIC_FIELDS) | set(_LOWERED_FIELDS)
    assert classified == {f.name for f in dataclasses.fields(Policy)}


def test_unclassified_field_would_fail():
    """The import-time completeness assert: a hypothetical new Policy field
    that lands in neither set breaks the classification identity (so the
    module fails to import until the field is classified)."""
    classified = (set(PARAM_FIELDS) - set(_STATE_TABLE_FIELDS)) \
        | set(STATIC_FIELDS) | set(_LOWERED_FIELDS)
    with_new = {f.name for f in dataclasses.fields(Policy)} | {"new_knob"}
    assert classified != with_new


def test_no_field_is_doubly_classified():
    own_params = set(PARAM_FIELDS) - set(_STATE_TABLE_FIELDS)
    assert not own_params & set(STATIC_FIELDS)
    assert not own_params & set(_LOWERED_FIELDS)
    assert not set(STATIC_FIELDS) & set(_LOWERED_FIELDS)


# ---------------------------------------------------------------------------
# policy_params / canonical_proto round-trip, pinned for all nine kinds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_policy_params_roundtrip(kind):
    pol = _policy(kind)
    p = policy_params(pol)
    assert set(p) == set(PARAM_FIELDS)
    assert all(isinstance(v, float) for v in p.values())
    # the state table lowers from the named states
    assert p["t_w"] == pol.state.t_w and p["t_s"] == pol.state.t_s
    assert p["power_frac"] == pol.state.power_frac
    assert p["t_w2"] == pol.deep.t_w and p["t_s2"] == pol.deep.t_s
    assert p["power_frac2"] == pol.deep.power_frac
    # the deep row is numerically unreachable exactly for single kinds
    if kind in SINGLE_KINDS:
        assert p["t_dst"] == float("inf")
    else:
        assert p["t_dst"] == pol.t_dst < float("inf")


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_canonical_proto_is_canonical(kind):
    pol = _policy(kind)
    proto = canonical_proto(pol)
    # same static structure, idempotent, and numerics-independent: any
    # numeric variant of the policy collapses onto the SAME proto (the
    # compile-cache key of the batched executor)
    assert static_key(proto) == static_key(pol)
    assert canonical_proto(proto) == proto
    variant = dataclasses.replace(pol, t_pdt=0.123, t_dst=0.456,
                                  bound=0.2, max_delay=1e-3)
    assert canonical_proto(variant) == proto
    assert proto.sleep_state == "deep_sleep"
    assert proto.deep_state == "deep_sleep"


def test_static_key_separates_kinds_not_numerics():
    keys = {static_key(_policy(k)) for k in ALL_KINDS}
    assert len(keys) == len(ALL_KINDS)
    a = _policy("dual")
    b = dataclasses.replace(a, t_dst=1.0, t_pdt=2.0, sleep_state="deep_sleep")
    assert static_key(a) == static_key(b)


# ---------------------------------------------------------------------------
# Predictor-state gating: dead histogram state is not allocated
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", SINGLE_KINDS[:2])
def test_init_state_gates_dead_predictor_state(kind):
    st = pb.init_state(8, Policy(kind=kind, t_pdt=1e-5))
    assert set(st) == {"tpdt"}
    assert st["tpdt"].shape == (8,)


def test_init_state_keeps_hist_when_recording():
    st = pb.init_state(8, Policy(kind="fixed", t_pdt=1e-5, record_hist=True,
                                 hist_bins=32))
    assert st["counts"].shape == (8, 32)
    assert st["sums"].shape == (8, 32)


@pytest.mark.parametrize("kind", ("perfbound", "perfbound_correct",
                                  "perfbound_dual", "predict"))
def test_init_state_adaptive_keeps_hist(kind):
    pol = dataclasses.replace(_policy(kind), hist_bins=16)
    st = pb.init_state(4, pol)
    assert st["counts"].shape == (4, 16)
    # the adaptive-demotion kinds carry a per-port t_dst vector; the
    # forecaster additionally carries its EWMA
    assert ("t_dst" in st) == (kind in ("perfbound_dual", "predict"))
    assert ("ewma" in st) == (kind == "predict")


# ---------------------------------------------------------------------------
# New kinds batch through the sweep: compile count pinned to static groups
# ---------------------------------------------------------------------------


def _tiny_trace(topo, n=6):
    nodes = np.arange(n, dtype=np.int64)
    tr = Trace(nodes=nodes, name="contract")
    for r in range(3):
        tr.compute(1e-4)
        tr.messages([[int(i), int((i + 1 + r) % n), 2048] for i in range(n)],
                    barrier=(r == 2))
    return tr


def test_perfbound_dual_state_under_scenario_grid_batching(topo, pm):
    """The PR-4 ``init_state`` gating contract, closed for the one cell it
    left untested: ``perfbound_dual`` carries an EXTRA predictor vector
    (the per-port adaptive ``t_dst``) that must batch per lane under the
    (T, B) multi-trace grid — B > 1 lanes with different initial
    t_dst/bound must not share state, its shape must track the (T, B, P)
    grid like every other carry, and every grid cell must match its own
    serial replay."""
    import repro.scenarios as SC
    from repro.core import replay
    from repro.core.sweep import sweep_scenarios
    from repro.traffic import plan as P

    names = ["dc-poisson", "dc-onoff"]
    traces = {n: SC.build_trace(SC.get_scenario(n).scaled(8), topo)
              for n in names}
    pols = {
        "pbd/1pct": Policy(kind="perfbound_dual", bound=0.01, t_dst=1e-3,
                           sleep_state="fast_wake",
                           deep_state="deep_sleep"),
        "pbd/5pct": Policy(kind="perfbound_dual", bound=0.05, t_dst=1e-4,
                           sleep_state="fast_wake",
                           deep_state="deep_sleep"),
    }
    assert len(group_policies(pols)) == 1

    # the (T, B) initial carry: per-lane t_dst vectors, not shared state
    plans = [P.compile_plan(traces[n], topo) for n in names]
    batch = P.stack_plans(plans, names=names)
    _, _, carry = replay.init_lanes_multi(list(pols.values()), batch)
    pred = carry[0]["pred"]
    T, B, Pn = 2, 2, topo.n_links + 1
    assert pred["t_dst"].shape == (T, B, Pn)
    assert pred["tpdt"].shape == (T, B, Pn)
    t_dst0 = np.asarray(pred["t_dst"])
    np.testing.assert_array_equal(t_dst0[:, 0], 1e-3)
    np.testing.assert_array_equal(t_dst0[:, 1], 1e-4)

    # and the full grid is bit-identical to per-cell serial replay
    import repro.core.simulator as S
    got = sweep_scenarios(traces, topo, pols, pm)
    for tn, tr in traces.items():
        for pn, pol in pols.items():
            want, _ = S.simulate_trace(tr, topo, pol, pm)
            assert got[tn][pn].as_dict() == want.as_dict(), f"{tn}/{pn}"


def test_new_kinds_batch_and_warm_sweep_compiles_nothing(topo, pm):
    """The dual-capable kinds (dual/coalesce/perfbound_dual/precoalesce/
    predict) group per kind — one static group per kind for two numeric
    lanes each — and numeric variants reuse the warmed programs: a second
    sweep with different timers compiles ZERO new programs."""
    tr = _tiny_trace(topo)

    def grid(scale):
        return {
            f"{k}{i}": dataclasses.replace(_policy(k), t_pdt=t * scale,
                                           t_dst=2 * t * scale)
            for k in DUAL_KINDS for i, t in ((0, 1e-5), (1, 1e-4))
        }

    g1 = grid(1.0)
    assert len(group_policies(g1)) == len(DUAL_KINDS)
    sweep_policies(tr, topo, g1, pm)                       # warm-up
    with count_compiles() as cc:
        out = sweep_policies(tr, topo, grid(3.0), pm)
    assert cc.count == 0, \
        f"numeric policy variants recompiled {cc.count} programs"
    assert set(out) == set(grid(3.0))
