"""PerfBound / PerfBoundCorrect predictor math (paper §2.5, §3.4)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import perfbound as pb
from repro.core.eee import Policy


# ---------------------------------------------------------------------------
# Eq. 1: hop-distance correction factor
# ---------------------------------------------------------------------------


def test_l_factor_paper_example():
    """The paper's worked example: 60 % of packets 4 hops away, 40 % 6 hops,
    1 % bound  ->  l = 0.01*(0.6/4 + 0.4/6) ~= 0.0022.

    (The paper's prose says '6 hops' twice but its Eq. 1 uses 4 and 6 —
    we follow the equation.)"""
    hops = jnp.zeros((pb.MAXH,)).at[4].set(60).at[5].set(0)
    # MAXH=6 rows 0..5; paper uses distances 4 and 6 — distance 6 exceeds the
    # Megafly max (5), so check the math generically with distances 4 and 5
    # first, then the exact paper numbers via a direct formula comparison.
    l = pb.l_factor(jnp.array([0, 0, 0, 0, 60.0, 40.0]), 0.01)
    want = 0.01 * (0.6 / 4 + 0.4 / 5)
    np.testing.assert_allclose(float(l), want, rtol=1e-12)
    # exact paper arithmetic (Eq. 1): 0.01*(0.6/4 + 0.4/6) ~= 0.0022
    assert abs(0.01 * (0.6 / 4 + 0.4 / 6) - 0.0022) < 1e-4


def test_l_factor_no_history_is_conservative():
    l = pb.l_factor(jnp.zeros((pb.MAXH,)), 0.01)
    np.testing.assert_allclose(float(l), 0.01)


def test_l_factor_monotone_in_distance():
    """Ports whose packets travel farther get a SMALLER l (fewer delayable
    packets per wake-up — each wake-up hits more hops)."""
    near = pb.l_factor(jnp.array([0, 100.0, 0, 0, 0, 0]), 0.01)
    far = pb.l_factor(jnp.array([0, 0, 0, 0, 0, 100.0]), 0.01)
    assert float(far) < float(near)


# ---------------------------------------------------------------------------
# Histogram management modes (§3.2)
# ---------------------------------------------------------------------------


def _insert(policy, gaps, times=None):
    st_ = pb.init_state(1, policy)
    times = times if times is not None else np.cumsum(gaps)
    for g, t in zip(gaps, times):
        st_ = pb.record_gaps(st_, jnp.array([0]), jnp.array([float(g)]),
                             jnp.array([float(t)]), jnp.array([True]), policy)
    return st_


def test_keep_all_histogram_counts():
    pol = Policy(kind="perfbound", hist_mode="keep_all", hist_bins=10,
                 hist_bin_width=1e-3)
    gaps = [0.5e-3, 1.5e-3, 1.5e-3, 9.7e-3, 50e-3]  # last clips to top bin
    st_ = _insert(pol, gaps)
    counts = np.asarray(st_["counts"][0])
    assert counts.sum() == 5
    assert counts[0] == 1 and counts[1] == 2 and counts[9] == 2
    np.testing.assert_allclose(float(st_["sums"][0].sum()), sum(gaps),
                               rtol=1e-12)


def test_self_clear_resets_after_n():
    pol = Policy(kind="perfbound", hist_mode="self_clear", hist_clear_n=4,
                 hist_bins=10, hist_bin_width=1e-3)
    st_ = _insert(pol, [1e-3] * 6)
    counts = np.asarray(st_["counts"][0])
    # cleared at the 4th insert; 2 survivors
    assert counts.sum() == 2
    assert int(st_["total"][0]) == 2


def test_circular_evicts_oldest():
    pol = Policy(kind="perfbound", hist_mode="circular", ring_n=3,
                 hist_bins=10, hist_bin_width=1e-3)
    st_ = _insert(pol, [0.5e-3, 1.5e-3, 2.5e-3, 3.5e-3, 4.5e-3])
    counts = np.asarray(st_["counts"][0])
    assert counts.sum() == 3                       # ring capacity
    assert counts[0] == 0 and counts[1] == 0      # oldest two evicted
    assert counts[2] == 1 and counts[3] == 1 and counts[4] == 1
    np.testing.assert_allclose(float(st_["sums"][0].sum()),
                               2.5e-3 + 3.5e-3 + 4.5e-3, rtol=1e-12)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(1e-6, 0.009), min_size=1, max_size=30),
       st.integers(2, 8))
def test_circular_matches_bruteforce(gaps, ring_n):
    """Ring-buffer histogram == histogram of the last ring_n values."""
    pol = Policy(kind="perfbound", hist_mode="circular", ring_n=ring_n,
                 hist_bins=10, hist_bin_width=1e-3)
    st_ = _insert(pol, gaps)
    live = gaps[-ring_n:]
    want = np.zeros(10)
    for g in live:
        want[min(int(g / 1e-3), 9)] += 1
    np.testing.assert_allclose(np.asarray(st_["counts"][0]), want)


# ---------------------------------------------------------------------------
# PerfBoundCorrect (§3.4): shift register + ratio FIFO + cf
# ---------------------------------------------------------------------------


def test_pbc_cf_no_misses_is_zero():
    pol = Policy(kind="perfbound_correct", n_r=8)
    st_ = pb.init_state(1, pol)
    for _ in range(5):  # five hits
        st_ = pb.record_outcomes(st_, jnp.array([0]), jnp.array([False]),
                                 jnp.array([1.0]), jnp.array([True]), pol)
    cf = pb.pbc_cf(st_["reg"], st_["ratio_log"], st_["n_seen"], pol)
    np.testing.assert_allclose(np.asarray(cf), [0.0])


def test_pbc_cf_formula():
    """cf = miss% x geomean(miss ratios): 2 misses (ratios 2 and 8) out of
    4 outcomes -> cf = 0.5 * sqrt(16) = 2.0."""
    pol = Policy(kind="perfbound_correct", n_r=8)
    st_ = pb.init_state(1, pol)
    seq = [(True, 2.0), (False, 1.0), (True, 8.0), (False, 1.0)]
    for miss, ratio in seq:
        st_ = pb.record_outcomes(st_, jnp.array([0]), jnp.array([miss]),
                                 jnp.array([ratio]), jnp.array([True]), pol)
    cf = pb.pbc_cf(st_["reg"], st_["ratio_log"], st_["n_seen"], pol)
    np.testing.assert_allclose(np.asarray(cf), [0.5 * 4.0], rtol=1e-12)


def test_pbc_shift_register_evicts_miss_and_ratio():
    """Wrapping the register drops the oldest outcome AND its slot-aligned
    ratio (the paper's FIFO semantics)."""
    pol = Policy(kind="perfbound_correct", n_r=4)
    st_ = pb.init_state(1, pol)

    def rec(miss, ratio):
        return pb.record_outcomes(st_, jnp.array([0]), jnp.array([miss]),
                                  jnp.array([ratio]), jnp.array([True]), pol)
    # fill: miss(4.0), hit, hit, hit
    st_ = rec(True, 4.0)
    for _ in range(3):
        st_ = rec(False, 1.0)
    cf0 = float(pb.pbc_cf(st_["reg"], st_["ratio_log"], st_["n_seen"], pol)[0])
    np.testing.assert_allclose(cf0, 0.25 * 4.0)
    # 5th outcome overwrites slot 0 (the miss): now 1 miss (ratio 9), 3 hits
    st_ = rec(True, 9.0)
    cf1 = float(pb.pbc_cf(st_["reg"], st_["ratio_log"], st_["n_seen"], pol)[0])
    np.testing.assert_allclose(cf1, 0.25 * 9.0)


def test_pbc_tpdt_capped_and_uplift():
    """PerfBoundCorrect never predicts below plain PerfBound and never above
    max_tpdt (DESIGN.md §4 interpretation)."""
    base = Policy(kind="perfbound", hist_bins=10, hist_bin_width=1e-3,
                  max_tpdt=5e-3, bound=0.01)
    pbc = Policy(kind="perfbound_correct", hist_bins=10, hist_bin_width=1e-3,
                 max_tpdt=5e-3, bound=0.01, n_r=4)
    lp = jnp.array([0])
    for miss_ratio in [0.0, 1.0, 100.0]:
        st_b = pb.init_state(1, base)
        st_c = pb.init_state(1, pbc)
        for g, t in [(1.1e-3, 1.0), (2.2e-3, 2.0), (0.4e-3, 3.0)]:
            args = (lp, jnp.array([g]), jnp.array([t]), jnp.array([True]))
            st_b = pb.record_gaps(st_b, *args, base)
            st_c = pb.record_gaps(st_c, *args, pbc)
            st_b = pb.record_hops(st_b, lp, jnp.array([3]),
                                  jnp.array([True]), base)
            st_c = pb.record_hops(st_c, lp, jnp.array([3]),
                                  jnp.array([True]), pbc)
        if miss_ratio > 0:
            st_c = pb.record_outcomes(st_c, lp, jnp.array([True]),
                                      jnp.array([miss_ratio]),
                                      jnp.array([True]), pbc)
        t_b = pb.compute_tpdt(st_b, lp, 4.0, 375e-9, base)
        t_c = pb.compute_tpdt(st_c, lp, 4.0, 375e-9, pbc)
        assert float(t_c[0]) >= float(t_b[0]) - 1e-15
        assert float(t_c[0]) <= pbc.max_tpdt + 1e-15


def test_compute_tpdt_all_matches_rowwise():
    pol = Policy(kind="perfbound", hist_bins=20, hist_bin_width=1e-4)
    st_ = pb.init_state(5, pol)
    rng = np.random.default_rng(1)
    for _ in range(20):
        lp = jnp.asarray(rng.integers(0, 5, 3))
        g = jnp.asarray(rng.uniform(1e-5, 2e-3, 3))
        t = jnp.asarray(rng.uniform(0, 1, 3))
        st_ = pb.record_gaps(st_, lp, g, t, jnp.array([True] * 3), pol)
    allv = pb.compute_tpdt_all(st_, 1.0, 375e-9, pol)
    for i in range(5):
        one = pb.compute_tpdt(st_, jnp.array([i]), 1.0, 375e-9, pol)
        np.testing.assert_allclose(np.asarray(one), np.asarray(allv[i:i+1]))


def test_policy_validation():
    with pytest.raises(AssertionError):
        Policy(kind="bogus")
    with pytest.raises(AssertionError):
        Policy(sleep_state="nap")
    with pytest.raises(AssertionError):
        Policy(kind="perfbound_correct", n_r=64)


# ---------------------------------------------------------------------------
# Demotion-threshold selection (perfbound_dual, DESIGN.md §6)
# ---------------------------------------------------------------------------


def _pbd(**kw):
    kw.setdefault("hist_bins", 10)
    kw.setdefault("hist_bin_width", 1e-3)
    return Policy(kind="perfbound_dual", sleep_state="fast_wake",
                  deep_state="deep_sleep", **kw)


def test_deep_breakeven_prices_the_ladder():
    """R* = (extra wake + second down at wake power) / power gain — and a
    ladder that saves nothing prices demotion at +inf."""
    p = pb._params(_pbd(), None)
    want = ((p["t_w2"] - p["t_w"]) + p["t_s2"] * (1 - p["power_frac"])) \
        / (p["power_frac"] - p["power_frac2"])
    np.testing.assert_allclose(float(pb.deep_breakeven(p)), want, rtol=1e-12)
    flat = dict(p, power_frac2=p["power_frac"])
    assert float(pb.deep_breakeven(flat)) == float("inf")


def test_tdst_select_demotes_past_the_short_mode():
    """Bimodal gaps: a dominant short mode (bin 1) and a thin long tail
    (bin 9).  With the short mode in the suffix the conditional residual is
    diluted below R*, so the leftmost feasible threshold sits just PAST the
    short mode — deep sleep engages only for the long-tail gaps.  A
    heavy-tail-dominated histogram instead demotes at sleep onset, an
    unreachable residual never demotes, and no history falls back to the
    initial timer."""
    pol = _pbd()
    centers = np.asarray(pb.bin_centers(pol))
    tpdt = jnp.asarray(0.5e-3)
    counts = jnp.zeros((10,)).at[1].set(50.0).at[9].set(2.0)
    sums = counts * jnp.asarray(centers)
    # residual at bins 0/1 = 0.094/52 - T < 2e-3 (diluted); from bin 2 the
    # suffix is the pure 9.5 ms tail -> residual 7 ms: feasible
    t = pb.tdst_select(counts, sums, tpdt, jnp.asarray(2e-3),
                       jnp.asarray(52.0), pol)
    np.testing.assert_allclose(float(t), centers[2] - 0.5e-3, rtol=1e-12)
    # tail-dominated histogram: bin 0 already feasible -> demote at onset
    heavy = jnp.zeros((10,)).at[1].set(50.0).at[9].set(20.0)
    t0bin = pb.tdst_select(heavy, heavy * jnp.asarray(centers), tpdt,
                           jnp.asarray(1e-3), jnp.asarray(70.0), pol)
    np.testing.assert_allclose(float(t0bin), 0.0, atol=1e-15)
    # an unreachable residual (beyond the whole histogram) -> never demote
    t_inf = pb.tdst_select(counts, sums, tpdt, jnp.asarray(1.0),
                           jnp.asarray(52.0), pol)
    assert float(t_inf) == float("inf")
    # no history yet -> the policy's initial timer
    t0 = pb.tdst_select(counts, sums, tpdt, jnp.asarray(2e-3),
                        jnp.asarray(0.0), pol)
    np.testing.assert_allclose(float(t0), pol.t_dst, rtol=1e-12)


def test_select_massless_histogram_falls_back():
    """Satellite audit: histograms whose COUNTS are all zero — total == 0
    (no history yet) or total > 0 with zeroed mass (decay underflow /
    fault-invalidated rows) — must fall back to the policy's initial
    timers.  Without the mass guard the all-feasible suffix picks bin 0
    and returns its (empty-bin) center instead."""
    pol = _pbd()
    z = jnp.zeros((10,))
    for total in (0.0, 3.0):
        t = pb.tpdt_select(z, z, jnp.asarray(5.0), jnp.asarray(total), pol)
        np.testing.assert_allclose(float(t), pol.tpdt_init, rtol=1e-12)
        td = pb.tdst_select(z, z, jnp.asarray(5e-4), jnp.asarray(2e-3),
                            jnp.asarray(total), pol)
        np.testing.assert_allclose(float(td), pol.t_dst, rtol=1e-12)


def test_bin_index_boundaries_linear():
    """Satellite audit: exact bin edges, zero gaps, and beyond-range gaps
    all map to a VALID bin (no -1 / out-of-range scatter drop)."""
    pol = Policy(kind="perfbound", hist_bins=10, hist_bin_width=1e-3)
    gaps = jnp.asarray([0.0, 1e-3, 2e-3 - 1e-9, 5e-3, 9e-3, 1.0])
    idx = np.asarray(pb.bin_index(gaps, pol))
    assert idx.tolist() == [0, 1, 1, 5, 9, 9]


def test_bin_index_boundaries_log():
    """Log binning: below-first-edge clamps to bin 0 (not negative), the
    top edge and beyond clamp to the last bin, and every interior edge
    lands in range."""
    pol = Policy(kind="perfbound", hist_bins=8, hist_log_bins=True,
                 hist_log_min=1e-6, hist_log_max=1.0)
    idx = np.asarray(pb.bin_index(jnp.asarray([1e-9, 1e-6, 1.0, 10.0]),
                                  pol))
    assert idx[0] == 0 and idx[1] == 0
    assert idx[2] == 7 and idx[3] == 7
    edges = np.exp(np.linspace(np.log(1e-6), np.log(1.0), 9))
    interior = np.asarray(pb.bin_index(jnp.asarray(edges[1:-1]), pol))
    assert ((interior >= 0) & (interior < 8)).all()


def test_bin_index_edge_values_conserve_mass():
    """Every inserted edge-value gap lands in SOME bin: histogram mass
    equals the insert count (nothing scatter-dropped)."""
    pol = Policy(kind="perfbound", hist_mode="keep_all", hist_bins=10,
                 hist_bin_width=1e-3)
    gaps = [1e-12, 1e-3, 2e-3, 9.9999e-3, 5.0]
    st_ = _insert(pol, gaps)
    np.testing.assert_allclose(float(st_["counts"][0].sum()), len(gaps),
                               rtol=1e-12)
    np.testing.assert_allclose(float(st_["total"][0]), len(gaps),
                               rtol=1e-12)


def test_fused_tpdt_tdst_matches_separate_calls():
    """The hot-path fusion (one gather + shared suffix cumsum) is exactly
    the two separate selections."""
    pol = _pbd()
    st_ = pb.init_state(3, pol)
    rng_ = np.random.default_rng(4)
    for _ in range(15):
        lp = jnp.asarray(rng_.integers(0, 3, 2))
        g = jnp.asarray(rng_.uniform(1e-4, 8e-3, 2))
        t = jnp.asarray(rng_.uniform(0, 1, 2))
        st_ = pb.record_gaps(st_, lp, g, t, jnp.array([True, True]), pol)
        st_ = pb.record_hops(st_, lp, jnp.array([2, 3]),
                             jnp.array([True, True]), pol)
    lp = jnp.arange(3)
    t_fused, td_fused = pb.compute_tpdt_tdst(st_, lp, 1.0, 375e-9, pol)
    t_sep = pb.compute_tpdt(st_, lp, 1.0, 375e-9, pol)
    td_sep = pb.compute_tdst(st_, lp, t_sep, pol)
    np.testing.assert_array_equal(np.asarray(t_fused), np.asarray(t_sep))
    np.testing.assert_array_equal(np.asarray(td_fused), np.asarray(td_sep))


def test_compute_tdst_threshold_never_negative():
    """A t_PDT beyond the selected bin clamps the timer at 0 (demote at
    sleep onset), never negative."""
    pol = _pbd()
    st_ = pb.init_state(1, pol)
    for g, t in [(2.5e-3, 1.0), (2.6e-3, 2.0), (9.5e-3, 3.0)]:
        st_ = pb.record_gaps(st_, jnp.array([0]), jnp.array([g]),
                             jnp.array([t]), jnp.array([True]), pol)
    t = pb.compute_tdst(st_, jnp.array([0]), jnp.asarray([5e-3]), pol)
    assert float(t[0]) >= 0.0


# ---------------------------------------------------------------------------
# Recency-biased histogram (beyond-paper; the paper's §5 future work)
# ---------------------------------------------------------------------------


def test_hist_decay_geometric_counts():
    """n same-bin inserts with decay d leave count = sum_i d^i."""
    d = 0.5
    pol = Policy(kind="perfbound", hist_mode="keep_all", hist_decay=d,
                 hist_bins=10, hist_bin_width=1e-3)
    st_ = _insert(pol, [0.5e-3] * 4)
    want = sum(d ** i for i in range(4))     # newest has weight 1
    np.testing.assert_allclose(float(st_["counts"][0, 0]), want, rtol=1e-12)


def test_hist_decay_forgets_regime_change():
    """After a regime shift (ms-scale -> µs-scale gaps) the decayed
    histogram's mass concentrates in the NEW regime while keep-all still
    votes for the old one; and under a tight degradation budget the
    decayed predictor therefore finds a feasible (small) t_PDT where
    keep-all is pinned high by its 60-sample ms tail."""
    mk = lambda dec: Policy(kind="perfbound", hist_mode="keep_all",
                            hist_decay=dec, hist_bins=200,
                            hist_bin_width=10e-6, bound=0.01)
    gaps = [5e-3] * 60 + [20e-6] * 20        # regime shift at t=60
    hists = {}
    for name, dec in (("keep", 1.0), ("decay", 0.8)):
        st_ = _insert(mk(dec), gaps)
        hists[name] = np.asarray(st_["counts"][0])
    top, new_bin = 199, 2                     # 5 ms clips to top; 20 µs->2
    assert hists["keep"][top] > hists["keep"][new_bin]      # old regime wins
    assert hists["decay"][new_bin] > hists["decay"][top]    # new regime wins
    # equal tight budget N=6: keep-all's 60-count ms tail is infeasible
    # until far-right bins; the decayed tail (<0.1) is feasible at bin 2
    centers = pb.bin_centers(mk(1.0))
    for name, want_low in (("keep", False), ("decay", True)):
        t = float(pb.tpdt_select(jnp.asarray(hists[name]),
                                 jnp.asarray(hists[name]) * centers,
                                 jnp.asarray(6.0), jnp.asarray(80.0),
                                 mk(1.0)))
        assert (t < 1e-4) == want_low, (name, t)


def test_hist_decay_policy_validation():
    with pytest.raises(AssertionError):
        Policy(hist_decay=0.0)
    with pytest.raises(AssertionError):
        Policy(hist_mode="circular", hist_decay=0.9)
