"""Minimal in-repo fallback for ``hypothesis`` (see requirements-dev.txt).

The real library is the preferred test dependency; this shim only exists so
the tier-1 suite collects and runs in hermetic environments where installing
it is not possible.  It implements the small strategy surface the test-suite
actually uses (integers, floats, lists, sampled_from, booleans, data,
``.map``, and ``hypothesis.extra.numpy.arrays``) with deterministic
per-test seeding: @given draws ``max_examples`` pseudo-random examples and
runs the test body once per example.  No shrinking, no database, no health
checks — failures report the drawn values instead.

Installed lazily from ``conftest.py`` via :func:`install`, which registers
fake ``hypothesis``, ``hypothesis.strategies`` and ``hypothesis.extra.numpy``
modules in ``sys.modules`` only when the real package is absent.
"""
from __future__ import annotations

import inspect
import sys
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    def __init__(self, draw, label="strategy"):
        self._draw = draw
        self._label = label

    def draw(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return Strategy(lambda rng: fn(self._draw(rng)),
                        f"{self._label}.map")

    def __repr__(self):
        return f"<stub {self._label}>"


class DataObject:
    """Supports ``data.draw(strategy)`` inside a test body."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.draw(self._rng)


def integers(min_value, max_value):
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)),
                    f"integers({min_value}, {max_value})")


def floats(min_value=0.0, max_value=1.0, *, allow_nan=None,
           allow_infinity=None, allow_subnormal=None, width=64,
           exclude_min=False, exclude_max=False):
    lo, hi = float(min_value), float(max_value)

    def draw(rng):
        x = float(rng.uniform(lo, hi))
        if width == 32:
            x = float(np.float32(x))
            # float32 rounding must not escape the requested interval
            x = min(max(x, lo), hi)
        return x

    return Strategy(draw, f"floats({lo}, {hi})")


def booleans():
    return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans")


def sampled_from(elements):
    seq = list(elements)
    return Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))],
                    "sampled_from")


def lists(elements, *, min_size=0, max_size=None, unique=False):
    cap = max_size if max_size is not None else min_size + 10

    def draw(rng):
        n = int(rng.integers(min_size, cap + 1))
        out, seen = [], set()
        attempts = 0
        while len(out) < n and attempts < 100 * (n + 1):
            v = elements.draw(rng)
            attempts += 1
            if unique:
                key = v
                if key in seen:
                    continue
                seen.add(key)
            out.append(v)
        return out

    return Strategy(draw, "lists")


def just(value):
    return Strategy(lambda rng: value, "just")


def one_of(*strategies):
    return Strategy(
        lambda rng: strategies[int(rng.integers(0, len(strategies)))].draw(rng),
        "one_of")


def data():
    return Strategy(lambda rng: DataObject(rng), "data")


def composite(fn):
    def builder(*args, **kw):
        return Strategy(lambda rng: fn(DataObject(rng).draw, *args, **kw),
                        f"composite({fn.__name__})")
    return builder


def _np_arrays(dtype, shape, *, elements=None, fill=None, unique=False):
    if isinstance(shape, int):
        shape = (shape,)

    def draw(rng):
        size = int(np.prod(shape)) if len(shape) else 1
        if elements is None:
            flat = rng.uniform(0, 1, size)
        else:
            flat = [elements.draw(rng) for _ in range(size)]
        return np.asarray(flat, dtype=dtype).reshape(shape)

    return Strategy(draw, f"arrays({np.dtype(dtype)}, {shape})")


def _seed_for(fn):
    return zlib.adler32(f"{fn.__module__}.{fn.__qualname__}".encode())


def given(*given_args, **given_kwargs):
    def decorate(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        # hypothesis maps positional strategies onto the RIGHTMOST params;
        # remaining (leftmost) params stay visible so pytest injects fixtures
        n_pos = len(given_args)
        kw_names = set(given_kwargs)
        remaining = [p for p in (params[:len(params) - n_pos]
                                 if n_pos else params)
                     if p.name not in kw_names]
        pos_names = [p.name for p in params[len(params) - n_pos:]]
        base_seed = _seed_for(fn)

        def wrapper(*args, **kwargs):
            # @settings may sit above OR below @given (hypothesis allows
            # both): check the wrapper first, then the inner test
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            for i in range(n):
                rng = np.random.default_rng((base_seed, i))
                # drawn values go by NAME (rightmost params): fixtures
                # arrive from pytest as kwargs, so positional passing would
                # collide with them
                drawn = {name: s.draw(rng)
                         for name, s in zip(pos_names, given_args)}
                drawn.update({k: s.draw(rng)
                              for k, s in given_kwargs.items()})
                try:
                    fn(*args, **kwargs, **drawn)
                except _Assumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"hypothesis-stub example {i} failed with drawn "
                        f"values {drawn!r}: {e!r}") from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.__signature__ = sig.replace(parameters=remaining)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return decorate


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn
    return decorate


settings.register_profile = lambda *a, **k: None
settings.load_profile = lambda *a, **k: None


class _Assumption(Exception):
    pass


def assume(condition):
    if not condition:
        raise _Assumption()
    return True


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None


def install():
    """Register the stub under the ``hypothesis`` module names (no-op when
    the real library is importable)."""
    try:
        import hypothesis  # noqa: F401
        return False
    except ImportError:
        pass

    root = types.ModuleType("hypothesis")
    root.__doc__ = __doc__
    root.given = given
    root.settings = settings
    root.assume = assume
    root.HealthCheck = HealthCheck
    root.example = lambda *a, **k: (lambda f: f)
    root.note = lambda *a, **k: None

    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "just", "one_of", "data", "composite"):
        setattr(strat, name, globals()[name])

    extra = types.ModuleType("hypothesis.extra")
    extra_np = types.ModuleType("hypothesis.extra.numpy")
    extra_np.arrays = _np_arrays

    root.strategies = strat
    extra.numpy = extra_np
    root.extra = extra

    sys.modules["hypothesis"] = root
    sys.modules["hypothesis.strategies"] = strat
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = extra_np
    return True
