"""Decoupled (per-port, kernel-backed) policy evaluation vs the coupled
simulator — the TPU-native fast path's approximation contract."""
import numpy as np
import pytest

from repro.core import decoupled as D
from repro.core import simulator as S
from repro.core.eee import Policy, PowerModel
from repro.traffic.generators import small_apps
from repro.traffic.trace import Trace


def _events(topo, pm, trace):
    base = Policy(kind="none")
    res, events = S.simulate_trace(trace, topo, base, pm,
                                   collect_events=True)
    return res, events


def test_events_to_streams_basic(topo, pm):
    nodes = np.arange(2, dtype=np.int64)
    tr = Trace(nodes=nodes, name="t")
    tr.messages([[0, 1, 50_000_000]])           # 1 ms serialization
    tr.compute(0.01)
    tr.messages([[0, 1, 50_000_000]], barrier=True)
    res, events = _events(topo, pm, tr)
    gaps, durs, tail = D.events_to_streams(events, topo.n_links,
                                           res.makespan)
    g, d = np.asarray(gaps), np.asarray(durs)
    used = np.nonzero(d.sum(0))[0]
    assert len(used) == 2                        # the two node links
    for l in used:
        busy = d[:, l].sum()
        np.testing.assert_allclose(busy, 2e-3, rtol=1e-6)
    # gap before second transmission ~ 10 ms compute
    second_gaps = np.sort(g[:, used[0]])[::-1]
    assert second_gaps[0] >= 0.9e-2


def test_overlapping_intervals_merged(topo, pm):
    """Both directions of a duplex link merge into one busy window."""
    nodes = np.arange(2, dtype=np.int64)
    tr = Trace(nodes=nodes, name="t")
    tr.messages([[0, 1, 50_000_000], [1, 0, 50_000_000]], barrier=True)
    res, events = _events(topo, pm, tr)
    gaps, durs, tail = D.events_to_streams(events, topo.n_links,
                                           res.makespan)
    d = np.asarray(durs)
    used = np.nonzero(d.sum(0))[0]
    for l in used:
        n_intervals = (d[:, l] > 0).sum()
        assert n_intervals == 1                  # merged duplex overlap


def test_decoupled_matches_coupled_hit_miss_counts(topo, pm):
    """For a fixed-PDT policy on a sparse trace (no queueing feedback) the
    decoupled replay reproduces the coupled simulator's transition counts
    and energy to first order."""
    tr = small_apps(topo, n_nodes=8)["alexnet"]
    res0, events = _events(topo, pm, tr)

    for t_pdt in (10e-6, 1e-3, 0.1):
        pol = Policy(kind="fixed", t_pdt=t_pdt, sleep_state="deep_sleep")
        coupled, _ = S.simulate_trace(tr, topo, pol, pm)
        gaps, durs, tail = D.events_to_streams(events, topo.n_links,
                                               res0.makespan)
        dec = D.evaluate_fixed(gaps, durs, tail, t_pdt, pol, pm)
        n_wake_dec = float(np.asarray(dec["n_wake"]).sum())
        # counts agree within 15 % (feedback shifts borderline gaps)
        if coupled.n_wake_transitions:
            assert abs(n_wake_dec - coupled.n_wake_transitions) \
                <= 0.15 * coupled.n_wake_transitions + 2
        # link energy within 10 %
        assert abs(dec["link_energy"] - coupled.link_energy) \
            <= 0.10 * coupled.link_energy


def test_sweep_policies_monotone_energy(topo, pm):
    """Across t_PDT values, wake time is monotone non-decreasing in t_PDT
    (more conservative -> more awake) on a fixed event stream."""
    tr = small_apps(topo, n_nodes=8)["lammps"]
    res0, events = _events(topo, pm, tr)
    pol = Policy(kind="fixed", sleep_state="deep_sleep")
    sweep = D.sweep_policies(events, topo.n_links, res0.makespan,
                             [0.0, 1e-5, 1e-4, 1e-3, 1e-2, 1.0], pol, pm)
    keys = sorted(sweep)
    wake = [sweep[t]["wake_time"] for t in keys]
    # monotone up to the transition-overhead slack: raising t_PDT past a gap
    # g trades (t_PDT_old + t_s + t_w) for g — each such crossing may REDUCE
    # wake time by at most t_w + t_s, so allow that much per lost transition
    st = pol.state
    for (ta, a), (tb, b) in zip(zip(keys, wake), zip(keys[1:], wake[1:])):
        lost = float(np.asarray(sweep[ta]["n_wake"]).sum()
                     - np.asarray(sweep[tb]["n_wake"]).sum())
        assert b >= a - max(lost, 0) * (st.t_w + st.t_s) - 1e-6
    # t_PDT = 1 s on a ~2 s trace: essentially always-on
    full = sweep[1.0]["wake_time"] + sweep[1.0]["sleep_time"]
    assert sweep[1.0]["wake_time"] > 0.5 * full


def test_perfbound_snapshot_prediction(topo, pm):
    """Kernel-backed one-shot PerfBound: bimodal gaps (many short, few very
    long) must select a t_PDT between the modes."""
    rng = np.random.default_rng(0)
    P = 8
    short = rng.uniform(1e-5, 5e-5, (400, P))
    lng = rng.uniform(0.5, 1.0, (20, P))
    gaps = np.concatenate([short, lng]).astype(np.float32)
    pol = Policy(kind="perfbound", bound=0.01, hist_bin_width=10e-6,
                 max_tpdt=10e-3, sleep_state="deep_sleep")
    t = D.perfbound_snapshot_tpdt(gaps, t_elapsed=20.0, hop_mean=3.0,
                                  policy=pol)
    t = np.asarray(t)
    # budget N = 0.01/3 * 20 / 4.48e-6 ~ 1.5e4 >> 420 samples: everything is
    # affordable -> t_PDT lands at/below the short mode (aggressive)
    assert (t <= 1e-4).all()
    # a tight window (X small) forces conservative prediction
    t2 = np.asarray(D.perfbound_snapshot_tpdt(
        gaps, t_elapsed=1e-3, hop_mean=3.0, policy=pol))
    assert (t2 >= t).all()


def test_ref_and_kernel_paths_agree_end_to_end(topo, pm):
    tr = small_apps(topo, n_nodes=8)["mlwf"]
    res0, events = _events(topo, pm, tr)
    gaps, durs, tail = D.events_to_streams(events, topo.n_links,
                                           res0.makespan)
    pol = Policy(kind="fixed", sleep_state="fast_wake")
    a = D.evaluate_fixed(gaps, durs, tail, 1e-4, pol, pm, use_ref=False)
    b = D.evaluate_fixed(gaps, durs, tail, 1e-4, pol, pm, use_ref=True)
    np.testing.assert_allclose(a["link_energy"], b["link_energy"],
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(a["n_wake"]),
                               np.asarray(b["n_wake"]))


def test_decoupled_dual_mode_ladder(topo, pm):
    """The dual-mode per-port evaluation: kernel == ref, the ladder's
    energy sits between fast-wake-only and deep-sleep-only on the same
    streams, and long gaps land in the deep account."""
    tr = small_apps(topo, n_nodes=8)["lammps"]
    res0, events = _events(topo, pm, tr)
    gaps, durs, tail = D.events_to_streams(events, topo.n_links,
                                           res0.makespan)
    t_pdt = 1e-5
    fw = Policy(kind="fixed", t_pdt=t_pdt, sleep_state="fast_wake")
    ds = Policy(kind="fixed", t_pdt=t_pdt, sleep_state="deep_sleep")
    dual = Policy(kind="dual", t_pdt=t_pdt, t_dst=1e-4,
                  sleep_state="fast_wake", deep_state="deep_sleep")
    out = {}
    for name, pol in (("fw", fw), ("ds", ds), ("dual", dual)):
        a = D.evaluate_fixed(gaps, durs, tail, t_pdt, pol, pm, use_ref=False)
        b = D.evaluate_fixed(gaps, durs, tail, t_pdt, pol, pm, use_ref=True)
        for k in ("link_energy", "wake_time", "sleep_time", "sleep2_time"):
            np.testing.assert_allclose(a[k], b[k], rtol=1e-5, atol=1e-8,
                                       err_msg=f"{name}.{k}")
        out[name] = a
    assert float(np.asarray(out["dual"]["n_deep"]).sum()) > 0
    assert out["dual"]["sleep2_time"] > 0
    assert out["fw"]["sleep2_time"] == out["ds"]["sleep2_time"] == 0.0
    assert out["ds"]["link_energy"] <= out["dual"]["link_energy"] \
        <= out["fw"]["link_energy"]
