"""Perf-lever configs (§Perf) keep numerics: every variant combination
must produce finite losses and — where semantics are unchanged — the same
loss/gradients as the defaults."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.training.loop import init_train_state, make_loss_fn


def _loss_and_gsum(cfg, state, batch):
    loss, grads = jax.value_and_grad(
        lambda p: make_loss_fn(cfg)(p, batch)[0])(state["params"])
    gsum = sum(float(jnp.abs(g.astype(jnp.float32)).sum())
               for g in jax.tree.leaves(grads))
    return float(loss), gsum


def _batch(cfg, rng):
    return {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}


@pytest.mark.parametrize("arch,overrides,exact", [
    ("qwen2-1.5b", {"remat_policy": "save_coll"}, True),
    ("qwen2-1.5b", {"remat_policy": "none"}, True),
    ("qwen2-1.5b", {"act_shard": "seq"}, True),      # sharding-only: equal
    ("qwen2-1.5b", {"act_shard": "dmodel"}, True),
    ("rwkv6-7b", {"act_shard": "batch"}, True),
    ("qwen3-moe-30b-a3b", {"remat_policy": "save_coll"}, True),
    # dp dispatch changes capacity bucketing (per-group) -> loss close,
    # not identical
    ("qwen3-moe-30b-a3b", {"moe_dispatch": "dp"}, False),
    ("dbrx-132b", {"moe_dispatch": "dp", "remat_policy": "save_coll"},
     False),
])
def test_variant_numerics(arch, overrides, exact):
    cfg = get_config(arch).smoke()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    l0, g0 = _loss_and_gsum(cfg, state, batch)
    cfg_v = dataclasses.replace(cfg, **overrides)
    l1, g1 = _loss_and_gsum(cfg_v, state, batch)
    assert np.isfinite(l1) and np.isfinite(g1)
    if exact:
        np.testing.assert_allclose(l1, l0, rtol=1e-5)
        np.testing.assert_allclose(g1, g0, rtol=5e-3)
    else:
        np.testing.assert_allclose(l1, l0, rtol=5e-2)


def test_stub_attn_shape_contract():
    """attn_impl='stub' preserves shapes/dtypes (it is a traffic model,
    not a numeric one — never enabled outside the dry-run)."""
    cfg = dataclasses.replace(get_config("qwen2-1.5b").smoke(),
                              attn_impl="stub")
    from repro.models import model as M
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    out = M.forward(params, batch, cfg, mode="train")
    assert out["logits"].shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(out["logits"].astype(jnp.float32)).all())
