"""Launcher-layer integration: train loop with resume, power advisor."""
import json

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.power_advisor import (DEFAULT_POLICIES, advise,
                                        llm_trace_from_cell)
from repro.launch.train import train
from repro.topology.megafly import small_topology

CFG = get_config("qwen2-1.5b").smoke()


def test_train_runs_and_checkpoints(tmp_path):
    _, losses = train(CFG, steps=6, seq_len=16, global_batch=4,
                      ckpt_dir=tmp_path, save_every=3, log_every=100,
                      log=lambda *a: None)
    assert len(losses) == 6
    assert all(np.isfinite(l) for l in losses)
    steps = sorted(int(p.name[5:]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps and steps[-1] == 6


def test_train_resume_reproduces_stream(tmp_path):
    """Stop at step 4, resume to 8 == one uninterrupted 8-step run."""
    _, l_a1 = train(CFG, steps=4, seq_len=16, global_batch=4,
                    ckpt_dir=tmp_path, save_every=4, log_every=100,
                    log=lambda *a: None)
    _, l_a2 = train(CFG, steps=8, seq_len=16, global_batch=4,
                    ckpt_dir=tmp_path, save_every=100, resume=True,
                    log_every=100, log=lambda *a: None)
    _, l_b = train(CFG, steps=8, seq_len=16, global_batch=4,
                   log_every=100, log=lambda *a: None)
    np.testing.assert_allclose(l_a1 + l_a2, l_b, rtol=1e-4)


FAKE_CELL = {
    "arch": "fake-1b", "shape": "train_4k", "mesh": "16x16",
    "n_devices": 64, "status": "ok",
    "cost": {"flops": 1e12},
    "collectives": {
        "per_op": {"all-reduce": 3e8, "all-gather": 1e8},
        "per_axis": {"tp": 2.5e8, "dp": 1.5e8},
        "while_trip_counts": {"body": 4},
    },
}


def test_llm_trace_structure(topo):
    tr = llm_trace_from_cell(FAKE_CELL, topo, n_steps=2, tp_degree=16)
    assert len(tr.nodes) == 64
    # per step: 4 layers x (compute + TP rounds) + DP rounds
    msgs = tr.n_messages
    assert msgs > 0
    # TP allreduce within 16-node groups: 2*log2(16) rounds of 16 nodes x 4
    # groups x 4 layers x 2 steps + DP rounds
    assert tr.total_bytes > 0


def test_advise_from_fake_dryrun(tmp_path, topo):
    p = tmp_path / "fake-1b__train_4k__pod1.json"
    p.write_text(json.dumps(FAKE_CELL))
    out = advise("fake-1b", "train_4k", topo=topo, dryrun_dir=tmp_path,
                 n_steps=1, max_overhead_pct=5.0)
    assert out["recommended"] is not None
    assert set(out["table"]) == {"baseline", *DEFAULT_POLICIES}
    base = out["table"]["baseline"]
    assert base["exec_overhead_pct"] == 0.0
    tp, dp = out["tp_dp_bytes"]
    assert tp == 2.5e8 and dp == 1.5e8


def test_advise_empty_budget_falls_back_to_baseline(tmp_path, topo):
    """No policy fits an impossible budget: the advisor answers the
    always-on baseline (like ``frontier.budget_winner``), never None."""
    p = tmp_path / "fake-1b__train_4k__pod1.json"
    p.write_text(json.dumps(FAKE_CELL))
    out = advise("fake-1b", "train_4k", topo=topo, dryrun_dir=tmp_path,
                 n_steps=1, max_overhead_pct=-1.0)
    assert out["recommended"] == "baseline"
    assert out["table"]["baseline"]["exec_overhead_pct"] == 0.0


def test_llm_trace_small_cell_guards_degenerate_split(topo):
    """n_devices < tp_degree (e.g. an 8-device cell with the default
    tp_degree=16): the strided DP split used to produce EMPTY node groups
    and TP allreduce over a non-2**k remainder; the clamp keeps every
    emitted group a power of two >= 2."""
    for n_dev in (8, 12):
        cell = dict(FAKE_CELL, n_devices=n_dev)
        tr = llm_trace_from_cell(cell, topo, n_steps=1, tp_degree=16)
        assert len(tr.nodes) == n_dev
        assert tr.n_messages > 0 and tr.total_bytes > 0
        for step in tr.steps:
            if step.msgs is not None and len(step.msgs):
                assert (step.msgs[:, 0] != step.msgs[:, 1]).all()
    # a 1-device cell has no collective partners at all: compute-only trace
    tr = llm_trace_from_cell(dict(FAKE_CELL, n_devices=1), topo, n_steps=1)
    assert tr.n_messages == 0


def test_advise_rejects_failed_cell(tmp_path):
    p = tmp_path / "bad__train_4k__pod1.json"
    p.write_text(json.dumps({"status": "failed", "error": "x"}))
    with pytest.raises(ValueError):
        advise("bad", "train_4k", dryrun_dir=tmp_path)


def test_advise_scenario_recommends_within_budget():
    """The catalog front door of the auto-tuner: a scenario name + budget
    in, a budget-respecting policy recommendation + frontier out."""
    from repro.launch.power_advisor import advise_scenario
    from repro.tuning import tiny_space
    tiny = small_topology(n_groups=3, leaves=2, spines=2, nodes_per_leaf=2)
    out = advise_scenario("dc-poisson", budget_pct=1.0, topo=tiny,
                          n_nodes=8, rounds=1, space=tiny_space())
    assert out["scenario"] == "dc-poisson" and out["budget_pct"] == 1.0
    assert out["row"]["exec_overhead_pct"] <= 1.0
    assert out["policy"] is not None           # a real Policy won
    assert out["recommended"] != "baseline"
    assert out["row"]["link_energy_saved_pct"] > 0.0
    names = [p["policy"] for p in out["frontier"]]
    assert out["recommended"] in names or "baseline" in names
    assert out["rounds"][0]["cells"] > 0


def test_advise_scenario_rejects_unknown_name():
    from repro.launch.power_advisor import advise_scenario
    with pytest.raises(KeyError, match="unknown scenario"):
        advise_scenario("no-such-workload")
