"""MPI-collective expansion invariants: round structure, data conservation,
and semantic reachability (broadcast reaches everyone, reduce drains to
root, allreduce moves the bandwidth-optimal byte count)."""
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.traffic import collectives as C

NODES8 = np.arange(100, 108, dtype=np.int64)  # non-trivial global ids


def _flatten(rounds):
    return np.concatenate(rounds, axis=0)


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32])
def test_allreduce_round_and_byte_structure(n):
    nodes = np.arange(n, dtype=np.int64)
    nbytes = 1 << 20
    rounds = C.allreduce(nodes, nbytes)
    logn = n.bit_length() - 1
    assert len(rounds) == 2 * logn                   # RS + AG
    msgs = _flatten(rounds)
    # recursive halving-doubling total traffic: 2 * (n-1)/n * nbytes per rank
    per_rank = msgs[:, 2].sum() / n
    np.testing.assert_allclose(per_rank, 2 * (n - 1) / n * nbytes, rtol=0.01)
    # every round is a perfect matching (each rank sends and receives once)
    for r in rounds:
        assert sorted(r[:, 0].tolist()) == sorted(nodes.tolist())
        assert sorted(r[:, 1].tolist()) == sorted(nodes.tolist())


def test_broadcast_reaches_all():
    for root in (0, 3):
        rounds = C.broadcast(NODES8, 4096, root=root)
        have = {NODES8[root]}
        for r in rounds:
            for s, d, b in r:
                assert s in have, "sender must already hold the data"
                have.add(d)
        assert have == set(NODES8.tolist())
    # binomial tree: log2(n) rounds, n-1 messages total
    rounds = C.broadcast(NODES8, 4096)
    assert len(rounds) == 3
    assert sum(len(r) for r in rounds) == 7


def test_reduce_drains_to_root():
    for root in (0, 5):
        rounds = C.reduce(NODES8, 4096, root=root)
        alive = set(NODES8.tolist())
        for r in rounds:
            for s, d, b in r:
                assert s in alive and d in alive
                alive.discard(s)                     # sender's data merged
        assert alive == {NODES8[root]}
        assert sum(len(r) for r in rounds) == 7


def test_gather_single_round_to_root():
    rounds = C.gather(NODES8, 512, root=2)
    assert len(rounds) == 1
    assert (rounds[0][:, 1] == NODES8[2]).all()
    assert len(rounds[0]) == 7


def test_allgather_ring():
    rounds = C.allgather(NODES8, 512)
    assert len(rounds) == 7                          # n-1 rounds
    for r in rounds:
        np.testing.assert_array_equal(r[:, 1], np.roll(NODES8, -1))


def test_alltoall_bruck_rounds():
    rounds = C.alltoall(NODES8, 1 << 20)
    assert len(rounds) == 3                          # log2(8)
    for k, r in enumerate(rounds):
        np.testing.assert_array_equal(r[:, 1], np.roll(NODES8, -(1 << k)))
        assert (r[:, 2] == (1 << 20) // 2).all()


def test_p2p_halo_symmetric_neighbors():
    msgs = C.p2p_halo(NODES8, 256)[0]
    pairs = {(int(s), int(d)) for s, d, _ in msgs}
    assert all((d, s) in pairs for s, d in pairs)    # symmetric exchange
    assert all(s != d for s, d in pairs)


@pytest.mark.parametrize("fn", [C.allreduce, C.broadcast, C.reduce,
                                C.alltoall])
def test_power_of_two_required(fn):
    with pytest.raises(AssertionError):
        fn(np.arange(6), 1024)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from([2, 4, 8, 16]), st.integers(1, 1 << 24))
def test_collectives_use_only_participants(n, nbytes):
    nodes = np.arange(1000, 1000 + n, dtype=np.int64)
    allowed = set(nodes.tolist())
    for fn in (C.allreduce, C.broadcast, C.reduce, C.alltoall, C.allgather):
        for r in fn(nodes, nbytes):
            assert set(r[:, 0].tolist()) <= allowed
            assert set(r[:, 1].tolist()) <= allowed
            assert (r[:, 2] >= 1).all()
