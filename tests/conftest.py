"""Shared fixtures.  Importing repro.core enables jax x64 (the simulator
needs it); model tests use explicit dtypes and are unaffected."""
import numpy as np
import pytest

import _hypothesis_stub

# Prefer the real hypothesis (requirements-dev.txt); fall back to the in-repo
# deterministic stub so the suite still collects in hermetic environments.
_hypothesis_stub.install()

import repro.core  # noqa: F401  (enables x64 before any jax compute)
from repro.core.eee import Policy, PowerModel
from repro.topology.megafly import Megafly, small_topology


@pytest.fixture(scope="session")
def topo():
    """Small Megafly: 5 groups x 16 nodes = 80 nodes, fast to simulate."""
    return small_topology()


@pytest.fixture(scope="session")
def paper_topo():
    """The exact paper scenario (host-side only — cheap to construct)."""
    return Megafly()


@pytest.fixture(scope="session")
def pm():
    return PowerModel()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_policy(**kw):
    return Policy(**kw)
