"""Policy auto-tuner (repro.tuning): property-based invariants for the
pure frontier math, the golden-capture round-0 regression (vs both the
committed capture and serial ``simulate_trace``, bit-identically), the
dc-* acceptance gate (tuned winner >= the PR-4 fixed-grid incumbent under
the same budget), warm-round compile pinning, and the full-catalog
``tune_catalog`` smoke."""
import json
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # direct __main__ regeneration run
    import _hypothesis_stub
    _hypothesis_stub.install()
    from hypothesis import given, settings
    from hypothesis import strategies as st

from repro import scenarios as SC
from repro import tuning
from repro.core import simulator as S
from repro.core.eee import Policy, PowerModel
from repro.core.instrument import CompileGuardError, compile_guard
from repro.topology.megafly import small_topology
from repro.tuning import KindSpace, Knob, TunePoint

PM = PowerModel()
# 12-node Megafly: big enough for 8-node allocations, fast to replay
TINY = small_topology(n_groups=3, leaves=2, spines=2, nodes_per_leaf=2)

DC_NAMES = ["dc-poisson", "dc-hotspot", "dc-onoff", "dc-incast"]
GOLDEN_PATH = Path(__file__).parent / "data" / "tune_golden.json"

# The PR-4 suite's best-in-grid dc-* policy (dual-10us-200us) as it is
# named inside the tiny search space — the incumbent the tuned winner
# must never fall behind.
INCUMBENT = "dual(t_pdt=1e-05,t_dst=0.0002)"


# ---------------------------------------------------------------------------
# Property-based invariants of the pure selection math (no simulation)
# ---------------------------------------------------------------------------

VALS = st.lists(st.floats(0.0, 10.0), min_size=0, max_size=12)


def _points(degs, energies):
    return [TunePoint(f"p{i}", d, e)
            for i, (d, e) in enumerate(zip(degs, energies))]


@settings(max_examples=60)
@given(degs=VALS, energies=VALS)
def test_frontier_nondominated_and_sorted(degs, energies):
    pts = _points(degs, energies)
    fr = tuning.pareto_frontier(pts)
    # sorted by ascending degradation with strictly decreasing energy
    for a, b in zip(fr, fr[1:]):
        assert a.degradation <= b.degradation
        assert a.energy > b.energy
    # non-dominated: nothing in the pool dominates a frontier member
    for f in fr:
        assert not any(tuning.dominates(p, f) for p in pts)
    # complete: every off-frontier point is dominated or a value-duplicate
    for p in pts:
        if p not in fr:
            assert any(tuning.dominates(f, p)
                       or (f.degradation, f.energy)
                       == (p.degradation, p.energy) for f in fr), p


@settings(max_examples=60)
@given(degs=VALS, energies=VALS, budget=st.floats(0.0, 10.0))
def test_budget_winner_never_violates_budget(degs, energies, budget):
    pts = _points(degs, energies)
    w = tuning.budget_winner(pts, budget)
    feasible = [p for p in pts if p.degradation <= budget]
    if not feasible:
        assert w is None
    else:
        assert w.degradation <= budget
        assert w.energy == min(p.energy for p in feasible)


@settings(max_examples=60)
@given(degs=VALS, energies=VALS, degs2=VALS, energies2=VALS,
       budget=st.floats(0.0, 10.0))
def test_adding_points_never_worsens_winner(degs, energies, degs2,
                                            energies2, budget):
    """The refinement invariant in the small: the winner over a superset
    of points can only improve (so halving rounds can never return a
    policy worse than the coarse-grid incumbent)."""
    pts = _points(degs, energies)
    extra = [TunePoint(f"q{i}", d, e)
             for i, (d, e) in enumerate(zip(degs2, energies2))]
    w1 = tuning.budget_winner(pts, budget)
    w2 = tuning.budget_winner(pts + extra, budget)
    if w1 is not None:
        assert w2 is not None and w2.energy <= w1.energy


@settings(max_examples=60)
@given(degs=VALS, energies=VALS, budget=st.floats(0.0, 10.0),
       seed=st.integers(0, 2**31 - 1))
def test_tie_breaking_deterministic_under_permutation(degs, energies,
                                                      budget, seed):
    """Satellite invariant: bit-equal (degradation, energy) ties resolve
    by canonical name, independent of pool enumeration order — a warm
    tuner rerun that encounters candidates in a different order must
    reproduce the cold run's winner and survivor ranking exactly."""
    pts = _points(degs, energies)
    # shadow every point with a lexicographically-earlier alias carrying
    # IDENTICAL values: the alias must win its tie everywhere
    pool = pts + [TunePoint(f"a-{p.name}", p.degradation, p.energy)
                  for p in pts]
    perm = list(pool)
    np.random.default_rng(seed).shuffle(perm)
    w1 = tuning.budget_winner(pool, budget)
    w2 = tuning.budget_winner(perm, budget)
    assert w1 == w2
    if w1 is not None:
        best = [p for p in pool if p.degradation <= budget
                and (p.energy, p.degradation) == (w1.energy,
                                                  w1.degradation)]
        assert w1.name == min(p.name for p in best)
    assert [p.name for p in tuning.rank_candidates(pool, budget)] \
        == [p.name for p in tuning.rank_candidates(perm, budget)]
    assert tuning.select_survivors(pool, budget, 3) \
        == tuning.select_survivors(perm, budget, 3)


@settings(max_examples=60)
@given(degs=VALS, energies=VALS, budget=st.floats(0.0, 10.0),
       keep=st.integers(1, 5))
def test_survivor_selection(degs, energies, budget, keep):
    pts = _points(degs, energies) + [TunePoint(tuning.BASELINE_NAME,
                                               0.0, 99.0)]
    surv = tuning.select_survivors(pts, budget, keep)
    assert len(surv) <= keep
    assert all(p.name != tuning.BASELINE_NAME for p in surv)
    feasible = [p for p in pts if p.degradation <= budget
                and p.name != tuning.BASELINE_NAME]
    if feasible and surv:
        # the best feasible candidate always survives, ranked first
        assert surv[0].degradation <= budget
        assert surv[0].energy == min(p.energy for p in feasible)


# ---------------------------------------------------------------------------
# The dc-* search: acceptance gate + warm compile pinning
# ---------------------------------------------------------------------------

DC_BUDGET = 0.2          # the PR-4 "<= 0.2% overhead" operating point


@pytest.fixture(scope="module")
def dc_report():
    return tuning.tune_scenarios(TINY, DC_NAMES, budget_pct=DC_BUDGET,
                                 rounds=3, space=tuning.tiny_space(),
                                 keep=3, n_nodes=8, pm=PM)


def test_dc_frontiers_nondominated_and_budget_respected(dc_report):
    for sc, t in dc_report.scenarios.items():
        pts = list(t.points.values())
        assert t.frontier == tuning.pareto_frontier(pts), sc
        assert t.winner.degradation <= DC_BUDGET, sc
        assert t.winner == tuning.budget_winner(pts, DC_BUDGET), sc
        # the always-on baseline rides every pool (guaranteed fallback)
        assert tuning.BASELINE_NAME in t.points, sc


def test_dc_winner_beats_fixed_grid_incumbent(dc_report):
    """The acceptance gate: on every dc-* scenario the tuned winner saves
    at least as much link energy as PR 4's best-in-grid fixed policy
    (dual-10us-200us) at a degradation no worse than the same <= 0.2%
    budget the incumbent was measured under."""
    for sc, t in dc_report.scenarios.items():
        inc = t.points[INCUMBENT]        # the incumbent IS in round 0
        assert inc.round == 0
        assert t.winner.degradation <= DC_BUDGET, sc
        assert t.winner.energy <= inc.energy, sc
        assert t.winner.row["link_energy_saved_pct"] \
            >= inc.row["link_energy_saved_pct"], sc
        # and the search genuinely improved on the coarse grid somewhere
        assert t.winner.row["link_energy_saved_pct"] > 0.0, sc


def test_dc_winner_is_a_predictive_kind_beating_incumbent(dc_report):
    """The PR-6 acceptance gate: the predictive kinds (DESIGN.md §8) must
    actually WIN the extended search somewhere, not merely participate —
    on at least one dc-* scenario the budget winner is a predict or
    precoalesce policy saving strictly more link energy than the PR-5
    reactive incumbent at the same <= 0.2% budget."""
    predictive = {}
    for sc, t in dc_report.scenarios.items():
        w = t.winner
        if w.name != tuning.BASELINE_NAME \
                and w.policy.kind in ("predict", "precoalesce"):
            predictive[sc] = w
    assert predictive, "no dc-* scenario tuned to a predictive winner: " \
        + str({sc: t.winner.name for sc, t in dc_report.scenarios.items()})
    for sc, w in predictive.items():
        inc = dc_report.scenarios[sc].points[INCUMBENT]
        assert w.degradation <= DC_BUDGET, sc
        assert w.row["link_energy_saved_pct"] \
            > inc.row["link_energy_saved_pct"], sc


def test_dc_refinement_never_worse_than_coarse_incumbent(dc_report):
    """Satellite invariant on the real search: the final winner is never
    worse than the best round-0 (coarse grid) point of the same
    scenario."""
    for sc, t in dc_report.scenarios.items():
        r0 = [p for p in t.points.values() if p.round == 0]
        w0 = tuning.budget_winner(r0, DC_BUDGET)
        assert w0 is not None
        assert t.winner.energy <= w0.energy, sc
        assert any(p.round > 0 for p in t.points.values()), \
            "no refinement rounds actually ran"


def test_dc_warm_rerun_compiles_nothing_and_reproduces(dc_report):
    """The search is deterministic, so a warm identical rerun must reuse
    every program of the cold run — ALL rounds (coarse + refinements)
    compile 0 programs, hard-pinned by the instrument guard — and land on
    identical winners and frontiers."""
    with compile_guard("warm tune_scenarios", 0) as cc:
        warm = tuning.tune_scenarios(TINY, DC_NAMES, budget_pct=DC_BUDGET,
                                     rounds=3, space=tuning.tiny_space(),
                                     keep=3, n_nodes=8, pm=PM,
                                     compile_budget=0)
    assert cc.count == 0
    assert [r["compiles"] for r in warm.rounds] \
        == [0] * len(warm.rounds)
    assert len(warm.rounds) >= 2, "refinement rounds must have run"
    for sc in DC_NAMES:
        a, b = dc_report.scenarios[sc], warm.scenarios[sc]
        assert a.winner == b.winner, sc
        assert a.frontier == b.frontier, sc
        assert set(a.points) == set(b.points), sc


def test_compile_guard_trips_on_budget_overrun():
    from repro.core.instrument import count_compiles

    def _fresh_compile():
        import jax
        import jax.numpy as jnp
        # a shape/closure no other test compiles
        return jax.jit(lambda x: x * 3.14159 + 2.71828)(
            jnp.arange(7, dtype=jnp.float64))

    with count_compiles() as cc:
        _fresh_compile()
    if cc.count == 0:                    # cached from a previous run
        pytest.skip("probe program already cached")
    with pytest.raises(CompileGuardError, match="budget 0"):
        with compile_guard("probe", 0):
            import jax
            import jax.numpy as jnp
            jax.jit(lambda x: x * 1.61803 - 0.57721)(
                jnp.arange(11, dtype=jnp.float64))


# ---------------------------------------------------------------------------
# Golden capture: round-0 cells vs the committed record AND serial replay
# ---------------------------------------------------------------------------


def _golden_space():
    """A fixed 5-candidate space (4 kinds + implicit baseline) — small
    enough to commit, wide enough to cover single-state, ladder and
    adaptive-demotion FSM paths."""
    ladder = dict(sleep_state="fast_wake", deep_state="deep_sleep")
    return [
        KindSpace("fixed-fw", Policy(kind="fixed", sleep_state="fast_wake"),
                  (Knob("t_pdt", (1e-5,)),)),
        KindSpace("fixed-ds", Policy(kind="fixed", sleep_state="deep_sleep"),
                  (Knob("t_pdt", (1e-4,)),)),
        KindSpace("dual", Policy(kind="dual", **ladder),
                  (Knob("t_pdt", (1e-5,)),
                   Knob("t_dst", (2e-4,), step=4.0))),
        KindSpace("pbd", Policy(kind="perfbound_dual", **ladder),
                  (Knob("bound", (0.01,), step=4.0),)),
    ]


GOLDEN_SCENARIOS = ["dc-poisson", "dc-onoff"]


def _golden_report():
    return tuning.tune_scenarios(TINY, GOLDEN_SCENARIOS, budget_pct=1.0,
                                 rounds=1, space=_golden_space(),
                                 n_nodes=8, pm=PM)


def _golden_payload(report):
    return {
        "scenarios": {sc: {name: p.row
                           for name, p in t.points.items()}
                      for sc, t in report.scenarios.items()},
        "winners": {sc: t.winner.name
                    for sc, t in report.scenarios.items()},
    }


@pytest.fixture(scope="module")
def golden_report():
    return _golden_report()


def test_golden_capture_matches_committed(golden_report):
    """Round-0 tuner cells vs the committed capture: any drift in trace
    synthesis, replay numerics, or the relative-row protocol shows up
    here as a diff against a file in git."""
    want = json.loads(GOLDEN_PATH.read_text())
    got = _golden_payload(golden_report)
    assert got["winners"] == want["winners"]
    for sc, rows in want["scenarios"].items():
        assert set(got["scenarios"][sc]) == set(rows), sc
        for pol, row in rows.items():
            grow = got["scenarios"][sc][pol]
            assert set(grow) == set(row), (sc, pol)
            for k, v in row.items():
                np.testing.assert_allclose(
                    grow[k], v, rtol=1e-9, atol=1e-12,
                    err_msg=f"{sc}/{pol}.{k}")


def test_golden_round0_bit_identical_to_serial(golden_report):
    """Every round-0 cell of the tuner — riding the stacked multi-trace
    batched path — is bit-identical (==, not allclose) to a serial
    ``simulate_trace`` of the same (scenario, policy) cell."""
    grid, _ = tuning.space_candidates(_golden_space())
    for sc in GOLDEN_SCENARIOS:
        trace = SC.build_trace(SC.get_scenario(sc).scaled(8), TINY)
        base, _ev = S.simulate_trace(trace, TINY, Policy(kind="none"), PM)
        t = golden_report.scenarios[sc]
        base_dict = base.as_dict()
        for k, v in base_dict.items():
            assert t.baseline.as_dict()[k] == v, f"{sc}/baseline.{k}"
        for pol_name, pol in grid.items():
            want, _ev = S.simulate_trace(trace, TINY, pol, PM)
            row = t.points[pol_name].row
            for k, v in want.as_dict().items():
                assert row[k] == v, f"{sc}/{pol_name}.{k}"


# ---------------------------------------------------------------------------
# tune_catalog: the full 12-entry catalog
# ---------------------------------------------------------------------------


def _catalog_space():
    """Two searched kinds + baseline: enough structure to tune every
    catalog family while keeping the 12-scenario smoke fast."""
    return [
        KindSpace("fixed-fw", Policy(kind="fixed", sleep_state="fast_wake"),
                  (Knob("t_pdt", (1e-5, 1e-4)),)),
        KindSpace("dual", Policy(kind="dual", sleep_state="fast_wake",
                                 deep_state="deep_sleep"),
                  (Knob("t_pdt", (1e-5,)),
                   Knob("t_dst", (2e-4,), step=4.0))),
    ]


def test_tune_catalog_all_scenarios():
    names = SC.list_scenarios()
    assert len(names) == 12
    report = tuning.tune_catalog(TINY, budget_pct=1.0, rounds=2,
                                 space=_catalog_space(), keep=2,
                                 n_nodes=8, pm=PM)
    assert sorted(report.scenarios) == sorted(names)
    for sc, t in report.scenarios.items():
        assert t.frontier == tuning.pareto_frontier(t.points.values()), sc
        assert t.winner is not None and t.winner.degradation <= 1.0, sc
        assert len(t.frontier) >= 1
        # winner carries a reconstructible Policy (or is the baseline)
        if t.winner.name != tuning.BASELINE_NAME:
            assert isinstance(t.winner.policy, Policy)
    # refinement ran and its accounting is recorded per round
    assert report.rounds[0]["round"] == 0
    assert report.rounds[0]["scenarios"] == 12
    assert all(r["cells"] > 0 for r in report.rounds)


def test_space_rejects_baseline_label():
    """A user KindSpace labeled like the synthetic baseline point would
    shadow the guaranteed budget fallback — refused up front."""
    with pytest.raises(AssertionError, match="baseline"):
        tuning.space_candidates(
            [KindSpace(tuning.BASELINE_NAME,
                       Policy(kind="fixed", t_pdt=1e-5))])


def test_tune_rejects_bad_objective():
    with pytest.raises(AssertionError, match="objective"):
        tuning.tune_scenarios(TINY, ["dc-poisson"], n_nodes=8,
                              objective="makespan")


if __name__ == "__main__":
    # regenerate the committed golden capture:
    #   PYTHONPATH=src:tests python tests/test_tuning.py
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    payload = _golden_payload(_golden_report())
    GOLDEN_PATH.write_text(json.dumps(payload, indent=1, sort_keys=True)
                           + "\n")
    print(f"wrote {GOLDEN_PATH}")
