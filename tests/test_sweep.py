"""Batched policy-sweep engine: equivalence with the serial simulator,
static-structure grouping, and energy-accounting invariants."""
import numpy as np
import pytest

from repro.core import simulator as S
from repro.core import sweep as W
from repro.core.eee import (PARAM_FIELDS, Policy, policy_params, static_key)
from repro.traffic.generators import small_apps
from repro.traffic.trace import Trace

CHECK_FIELDS = ("makespan", "mean_latency", "max_latency", "n_messages",
                "link_energy", "switch_energy", "node_energy", "total_energy",
                "asleep_frac", "deep_frac", "n_wake_transitions", "hits", "misses",
                "deep_misses")


def _mini_trace(topo, n=12, seed=3):
    """A small Megafly trace with compute phases, cross-group traffic and
    barriers — enough structure to exercise latency feedback."""
    rng = np.random.default_rng(seed)
    nodes = np.arange(n, dtype=np.int64) * (topo.n_nodes // n)
    tr = Trace(nodes=nodes, name="mini")
    for r in range(4):
        tr.compute(rng.uniform(1e-5, 2e-3, n))
        msgs = [[int(nodes[i]), int(nodes[(i + 1 + r) % n]),
                 int(rng.integers(256, 1 << 16))] for i in range(n)]
        tr.messages(msgs, barrier=(r % 2 == 1))
    tr.compute(5e-3)
    tr.messages([[int(nodes[0]), int(nodes[-1]), 4096]], barrier=True)
    return tr


GRID = {
    "none": Policy(kind="none"),
    "fixed/fw/10us": Policy(kind="fixed", t_pdt=1e-5, sleep_state="fast_wake"),
    "fixed/ds/100us": Policy(kind="fixed", t_pdt=1e-4,
                             sleep_state="deep_sleep"),
    "fixed/ds/0": Policy(kind="fixed", t_pdt=0.0, sleep_state="deep_sleep"),
    "pb/ds/1pct": Policy(kind="perfbound", bound=0.01,
                         sleep_state="deep_sleep"),
    "pb/fw/5pct": Policy(kind="perfbound", bound=0.05,
                         sleep_state="fast_wake"),
    "pb/ds/ring": Policy(kind="perfbound", bound=0.01, hist_mode="circular",
                         ring_n=64, sleep_state="deep_sleep"),
    "pb/ds/clear": Policy(kind="perfbound", bound=0.02,
                          hist_mode="self_clear", hist_clear_n=50,
                          sleep_state="deep_sleep"),
    "pbc/ds/1pct": Policy(kind="perfbound_correct", bound=0.01,
                          sleep_state="deep_sleep"),
    "pbc/fw/2pct": Policy(kind="perfbound_correct", bound=0.02,
                          sleep_state="fast_wake"),
    # log-spaced bins and recency decay: the two configurations whose
    # batched program takes traced-param branches the serial path doesn't
    # (jnp bin_centers / per-lane hist_decay) — two lanes each so the
    # batch axis is genuinely exercised
    "pb/ds/log": Policy(kind="perfbound", bound=0.01, hist_log_bins=True,
                        sleep_state="deep_sleep"),
    "pb/fw/log8": Policy(kind="perfbound", bound=0.02, hist_log_bins=True,
                         hist_log_min=1e-8, sleep_state="fast_wake"),
    "pbc/ds/decay98": Policy(kind="perfbound_correct", bound=0.01,
                             hist_decay=0.98, sleep_state="deep_sleep"),
    "pbc/fw/decay9": Policy(kind="perfbound_correct", bound=0.02,
                            hist_decay=0.9, sleep_state="fast_wake"),
    # dual-mode FSM kinds (DESIGN.md §6): two lanes per kind so the batch
    # axis carries genuinely different ladder/coalescing numerics
    "dual/fast": Policy(kind="dual", t_pdt=1e-5, t_dst=5e-5,
                        sleep_state="fast_wake", deep_state="deep_sleep"),
    "dual/slow": Policy(kind="dual", t_pdt=1e-4, t_dst=2e-3,
                        sleep_state="fast_wake", deep_state="deep_sleep"),
    "coal/on": Policy(kind="coalesce", t_pdt=1e-5, t_dst=2e-4,
                      max_delay=5e-5, max_frames=8,
                      sleep_state="fast_wake", deep_state="deep_sleep"),
    "coal/off": Policy(kind="coalesce", t_pdt=1e-5, t_dst=2e-4,
                       max_delay=5e-5, max_frames=1,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
    "pbd/1pct": Policy(kind="perfbound_dual", bound=0.01,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
    "pbd/5pct": Policy(kind="perfbound_dual", bound=0.05, t_dst=1e-4,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
    # predictive FSM kinds (DESIGN.md §8): hold-at-source coalescing and
    # the forecast-driven timer ladder, two lanes each
    "pre/fast": Policy(kind="precoalesce", t_pdt=1e-5, t_dst=2e-4,
                       hold_delay=2e-5, hold_frames=4,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
    "pre/slow": Policy(kind="precoalesce", t_pdt=1e-5, t_dst=2e-4,
                       hold_delay=2e-4, hold_frames=16,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
    "pred/soft": Policy(kind="predict", t_pdt=1e-5, t_dst=2e-4,
                        forecast_weight=0.5, forecast_margin=2.0,
                        sleep_state="fast_wake", deep_state="deep_sleep"),
    "pred/hard": Policy(kind="predict", t_pdt=1e-5, t_dst=2e-4,
                        forecast_weight=1.0, forecast_margin=8.0,
                        sleep_state="fast_wake", deep_state="deep_sleep"),
}


# ---------------------------------------------------------------------------
# Policy factoring: static structure vs numeric parameter vector
# ---------------------------------------------------------------------------


def test_policy_params_covers_param_fields():
    p = policy_params(Policy(kind="fixed", t_pdt=3e-5,
                             sleep_state="fast_wake"))
    assert set(p) == set(PARAM_FIELDS)
    assert p["t_pdt"] == 3e-5
    assert p["t_w"] == Policy(sleep_state="fast_wake").state.t_w
    assert all(isinstance(v, float) for v in p.values())


def test_static_key_ignores_numeric_fields():
    a = Policy(kind="perfbound", bound=0.01, sleep_state="deep_sleep")
    b = Policy(kind="perfbound", bound=0.05, sleep_state="fast_wake",
               t_pdt=1.0, max_tpdt=1e-2, hist_bin_width=1e-5)
    assert static_key(a) == static_key(b)
    assert static_key(a) != static_key(Policy(kind="perfbound_correct"))
    assert static_key(a) != static_key(
        Policy(kind="perfbound", hist_mode="circular"))
    # decay participates only as a flag
    assert static_key(Policy(hist_decay=0.9)) == static_key(
        Policy(hist_decay=0.5))
    assert static_key(Policy(hist_decay=0.9)) != static_key(Policy())


def test_grouping_batches_paper_grid():
    """A paper-style 2x2x2 perfbound grid shares ONE static structure, so
    the ≥8-policy sweep runs as a single batched scan per chunk."""
    pols = {f"pb/{st}/{b}/{w:g}":
            Policy(kind="perfbound", bound=b, sleep_state=st,
                   hist_bin_width=w)
            for st in ("fast_wake", "deep_sleep")
            for b in (0.01, 0.02) for w in (1e-5, 1e-6)}
    assert len(pols) == 8
    groups = W.group_policies(pols)
    assert len(groups) == 1 and len(groups[0]) == 8


# ---------------------------------------------------------------------------
# Equivalence: sweep == serial replay, per policy, all four kinds
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def swept(topo, pm):
    tr = _mini_trace(topo)
    return tr, W.sweep_policies(tr, topo, GRID, pm)


@pytest.mark.parametrize("name", list(GRID))
def test_sweep_matches_serial(swept, topo, pm, name):
    tr, results = swept
    serial, _ = S.simulate_trace(tr, topo, GRID[name], pm)
    got = results[name].as_dict()
    want = serial.as_dict()
    for k in CHECK_FIELDS:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-9, atol=1e-12,
                                   err_msg=f"{name}.{k}")


def test_coalesce_parameter_curve_matches_serial(topo, pm):
    """A whole coalescing-window curve — max_delay x max_frames lanes —
    batches as ONE compiled replay of the coalesce static group, and every
    lane matches its own serial replay (the single-point coverage above
    never exercised these two knobs as vmapped curve axes)."""
    tr = _mini_trace(topo, n=10, seed=13)
    pols = {f"coal/{md:g}/{mf}": Policy(
                kind="coalesce", t_pdt=1e-5, t_dst=2e-4,
                max_delay=md, max_frames=mf,
                sleep_state="fast_wake", deep_state="deep_sleep")
            for md in (1e-5, 5e-5, 2e-4) for mf in (1, 4, 16)}
    assert len(W.group_policies(pols)) == 1        # one batched program
    got = W.sweep_policies(tr, topo, pols, pm)
    for name, pol in pols.items():
        want, _ = S.simulate_trace(tr, topo, pol, pm)
        for k in CHECK_FIELDS:
            np.testing.assert_allclose(
                got[name].as_dict()[k], want.as_dict()[k],
                rtol=1e-9, atol=1e-12, err_msg=f"{name}.{k}")
    # the knobs are live on the batch axis: deferral must move the
    # energy/latency numbers across the max_delay lanes once max_frames
    # allows coalescing...
    curve = {md: got[f"coal/{md:g}/16"].link_energy
             for md in (1e-5, 5e-5, 2e-4)}
    assert len(set(curve.values())) > 1, \
        f"max_delay lanes collapsed to one result: {curve}"
    # ...and a one-frame buffer (max_frames=1) disables deferral,
    # degenerating to the plain dual ladder exactly (DESIGN.md §6)
    dual, _ = S.simulate_trace(
        tr, topo, Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                         sleep_state="fast_wake", deep_state="deep_sleep"),
        pm)
    for md in (1e-5, 5e-5, 2e-4):
        for k in CHECK_FIELDS:
            np.testing.assert_allclose(
                got[f"coal/{md:g}/1"].as_dict()[k], dual.as_dict()[k],
                rtol=1e-12, err_msg=f"coal/{md:g}/1 vs dual: {k}")


def test_precoalesce_parameter_curve_matches_serial(topo, pm):
    """The hold-at-source window — hold_delay x hold_frames lanes — batches
    as ONE compiled replay of the precoalesce static group, every lane
    matches its own serial replay, the knobs are live on the batch axis,
    and a one-frame hold buffer degenerates to the plain dual ladder
    exactly (DESIGN.md §8)."""
    tr = _mini_trace(topo, n=10, seed=13)
    pols = {f"pre/{hd:g}/{hf}": Policy(
                kind="precoalesce", t_pdt=1e-5, t_dst=2e-4,
                hold_delay=hd, hold_frames=hf,
                sleep_state="fast_wake", deep_state="deep_sleep")
            for hd in (1e-5, 5e-5, 2e-4) for hf in (1, 4, 16)}
    assert len(W.group_policies(pols)) == 1        # one batched program
    got = W.sweep_policies(tr, topo, pols, pm)
    for name, pol in pols.items():
        want, _ = S.simulate_trace(tr, topo, pol, pm)
        for k in CHECK_FIELDS:
            np.testing.assert_allclose(
                got[name].as_dict()[k], want.as_dict()[k],
                rtol=1e-9, atol=1e-12, err_msg=f"{name}.{k}")
    curve = {hd: got[f"pre/{hd:g}/16"].link_energy
             for hd in (1e-5, 5e-5, 2e-4)}
    assert len(set(curve.values())) > 1, \
        f"hold_delay lanes collapsed to one result: {curve}"
    dual, _ = S.simulate_trace(
        tr, topo, Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                         sleep_state="fast_wake", deep_state="deep_sleep"),
        pm)
    for hd in (1e-5, 5e-5, 2e-4):
        for k in CHECK_FIELDS:
            np.testing.assert_allclose(
                got[f"pre/{hd:g}/1"].as_dict()[k], dual.as_dict()[k],
                rtol=1e-12, err_msg=f"pre/{hd:g}/1 vs dual: {k}")


def test_predict_parameter_curve_matches_serial(topo, pm):
    """The forecaster knobs — forecast_weight x forecast_margin lanes —
    batch as ONE compiled replay of the predict static group, every lane
    matches its own serial replay, and a zero-weight forecaster (EWMA off,
    every prediction falls back to the reactive timers) degenerates to the
    plain dual ladder exactly (DESIGN.md §8)."""
    tr = _mini_trace(topo, n=10, seed=13)
    pols = {f"pred/{fw:g}/{fm:g}": Policy(
                kind="predict", t_pdt=1e-5, t_dst=2e-4,
                forecast_weight=fw, forecast_margin=fm,
                sleep_state="fast_wake", deep_state="deep_sleep")
            for fw in (0.0, 0.5, 1.0) for fm in (1.0, 4.0)}
    assert len(W.group_policies(pols)) == 1        # one batched program
    got = W.sweep_policies(tr, topo, pols, pm)
    for name, pol in pols.items():
        want, _ = S.simulate_trace(tr, topo, pol, pm)
        for k in CHECK_FIELDS:
            np.testing.assert_allclose(
                got[name].as_dict()[k], want.as_dict()[k],
                rtol=1e-9, atol=1e-12, err_msg=f"{name}.{k}")
    dual, _ = S.simulate_trace(
        tr, topo, Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                         sleep_state="fast_wake", deep_state="deep_sleep"),
        pm)
    for fm in (1.0, 4.0):
        for k in CHECK_FIELDS:
            np.testing.assert_allclose(
                got[f"pred/0/{fm:g}"].as_dict()[k], dual.as_dict()[k],
                rtol=1e-12, err_msg=f"pred/0/{fm:g} vs dual: {k}")


def test_sweep_max_group_split_matches(topo, pm):
    """Splitting a group into sub-batches must not change results."""
    tr = _mini_trace(topo, n=8, seed=5)
    pols = {f"pb{b:g}": Policy(kind="perfbound", bound=b)
            for b in (0.01, 0.02, 0.03, 0.05)}
    full = W.sweep_policies(tr, topo, pols, pm)
    split = W.sweep_policies(tr, topo, pols, pm, max_group=1)
    for name in pols:
        np.testing.assert_allclose(
            [full[name].as_dict()[k] for k in CHECK_FIELDS],
            [split[name].as_dict()[k] for k in CHECK_FIELDS], rtol=1e-12)


def test_compare_policies_rides_sweep(topo, pm):
    """The §4 protocol wrapper produces the same table as serial runs."""
    tr = _mini_trace(topo, n=8, seed=7)
    pols = {"fixed": Policy(kind="fixed", t_pdt=1e-4,
                            sleep_state="deep_sleep"),
            "pbc": Policy(kind="perfbound_correct", bound=0.01)}
    out = S.compare_policies(tr, topo, pols, pm)
    base, _ = S.simulate_trace(tr, topo, Policy(kind="none"), pm)
    assert out["baseline"]["exec_overhead_pct"] == 0.0
    np.testing.assert_allclose(out["baseline"]["makespan"], base.makespan,
                               rtol=1e-12)
    for name, pol in pols.items():
        r, _ = S.simulate_trace(tr, topo, pol, pm)
        np.testing.assert_allclose(out[name]["makespan"], r.makespan,
                                   rtol=1e-9)
        np.testing.assert_allclose(
            out[name]["exec_overhead_pct"],
            100 * (r.makespan / base.makespan - 1), rtol=1e-6, atol=1e-9)


def test_sweep_handles_baseline_name_collision(topo, pm):
    tr = _mini_trace(topo, n=4, seed=11)
    out = S.compare_policies(
        tr, topo, {"__baseline__": Policy(kind="fixed", t_pdt=1e-4)}, pm)
    assert "baseline" in out and "__baseline__" in out
    assert out["__baseline__"]["makespan"] >= out["baseline"]["makespan"]


# ---------------------------------------------------------------------------
# Energy-accounting invariants (issue satellite): every second of every
# link's timeline lands at exactly one power level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["none", "fixed/ds/100us", "fixed/ds/0",
                                  "pb/ds/1pct", "pbc/ds/1pct", "dual/fast",
                                  "coal/on", "pbd/1pct"])
def test_close_out_accounts_full_makespan(topo, pm, name):
    """After close_out, time_wake + time_sleep ≈ makespan on every link
    (overshoot only, bounded by the wake/sleep transition extensions)."""
    pol = GRID[name]
    tr = _mini_trace(topo)
    res, _ = S.simulate_trace(tr, topo, pol, pm)

    # replay the same chunks to get the final net state for close_out
    net = S.init_net(topo.n_links, pol)
    ready = np.zeros(topo.n_nodes)
    for step in tr.steps:
        if step.compute_nodes is not None and len(step.compute_nodes):
            ready[step.compute_nodes] += step.compute_secs
        if step.msgs is not None and len(step.msgs):
            src, dst = step.msgs[:, 0], step.msgs[:, 1]
            nbytes = step.msgs[:, 2].astype(np.float64)
            t_inj = ready[src]
            order = np.argsort(t_inj, kind="stable")
            links, dirs, nhops = topo.routes(src[order], dst[order])
            msgs = S._pad_msgs(links, dirs, nhops, t_inj[order],
                               nbytes[order])
            net, out = S.sim_chunk(net, msgs, pol, pm, topo.n_links)
            np.maximum.at(ready, dst[order],
                          np.asarray(out[0])[:len(src)])
        if step.barrier:
            ready[tr.nodes] = ready[tr.nodes].max()

    t_end = float(ready[tr.nodes].max())
    np.testing.assert_allclose(t_end, res.makespan, rtol=1e-12)
    tw, ts, ts2 = (np.asarray(x) for x in
                   S.close_out(net, t_end, pol, topo.n_links))
    assert (tw >= -1e-12).all() and (ts >= -1e-12).all() \
        and (ts2 >= -1e-12).all()
    over = (tw + ts + ts2) - max(t_end, float(net["last_end"]
                                              [:topo.n_links].max()))
    assert (over > -1e-9).all(), "undershoot: unaccounted link time"
    bound = np.asarray(net["n_wake"][:topo.n_links]) * \
        (pol.state.t_w + pol.sync_overhead + pol.state.t_s) + \
        np.asarray(net["n_deep"][:topo.n_links]) * \
        (pol.deep.t_w + pol.sync_overhead + pol.deep.t_s) + 1e-9
    assert (over <= bound).all(), "overshoot beyond transition extensions"


def test_asleep_frac_in_unit_interval(swept):
    _, results = swept
    for name, res in results.items():
        assert 0.0 <= res.asleep_frac <= 1.0, name
        assert res.hits >= 0 and res.misses >= 0
        assert res.n_wake_transitions == res.misses, name


def test_none_policy_never_sleeps(swept, topo, pm):
    _, results = swept
    res = results["none"]
    assert res.asleep_frac == 0.0
    assert res.n_wake_transitions == 0
    assert res.misses == 0
    # link energy is exactly every port at wake power for the whole run
    want = 2 * pm.port_power * topo.n_links * res.makespan
    np.testing.assert_allclose(res.link_energy, want, rtol=1e-9)
