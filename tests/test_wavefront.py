"""Wavefront message-phase replay (DESIGN.md §10).

Three contracts:

* the plan-time wave schedule is a valid level schedule — it partitions
  each step's valid messages, waves are link-disjoint, and conflicting
  pairs land in waves that strictly follow their slot order;
* wavefront replay is ``==`` (bit-identical, not allclose) to the serial
  compiled executor across all nine policy kinds x Megafly + fat-tree —
  reordering commuting link-disjoint updates introduces ZERO numerical
  drift — and matches the step-loop reference at the equivalence suite's
  standard tolerance (the compiled serial path itself differs from the
  host reference by ~1 ulp in latency accumulation order, a pre-existing
  slop test_plan.py pins at rtol 1e-9);
* warm wavefront replays stay device-resident: 0 compiles, 0 transfers.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import replay
from repro.core import simulator as S
from repro.core.eee import Policy, PowerModel
from repro.core.instrument import count_compiles
from repro.topology.fattree import small_fattree
from repro.topology.megafly import small_topology
from repro.traffic import plan as P
from repro.traffic.trace import Trace

from test_plan import (CHECK_FIELDS, POLICIES, TOPOS, _assert_results_match,
                       traces)

PM = PowerModel()


def _assert_bit_identical(got, want, label=""):
    g, w = got.as_dict(), want.as_dict()
    for k in CHECK_FIELDS:
        assert np.asarray(g[k] == w[k]).all(), \
            f"{label}.{k}: {g[k]!r} != {w[k]!r}"


# ---------------------------------------------------------------------------
# Wave schedule properties (host twins of the executor's in-step pass)
# ---------------------------------------------------------------------------


@st.composite
def step_routes(draw):
    """Random per-step route sets: M messages x up to H hops over a small
    link id space (dense enough to exercise real conflicts)."""
    m = draw(st.integers(min_value=1, max_value=12))
    h = draw(st.integers(min_value=1, max_value=4))
    links = np.full((m, h), -1, np.int64)
    nhops = np.zeros((m,), np.int64)
    for i in range(m):
        nhops[i] = draw(st.integers(1, h))
        for j in range(int(nhops[i])):
            links[i, j] = draw(st.integers(0, 6))
    return links, nhops


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_wave_schedule_is_valid(data):
    links, nhops = data.draw(step_routes())
    m = links.shape[0]
    conf = P.step_conflicts(links, nhops)
    wave = P.wave_assign(conf)

    # partition: every message gets exactly one wave id in [1, W]
    W = int(wave.max())
    assert wave.shape == (m,)
    assert (wave >= 1).all() and (wave <= W).all()
    for w in range(1, W + 1):
        assert (wave == w).any(), f"empty wave {w}"

    # link-disjoint: no conflicting pair shares a wave
    same = wave[:, None] == wave[None, :]
    assert not (conf & same).any(), "conflicting pair in one wave"

    # ordering contract: conflicting pairs keep slot order across waves
    i, j = np.nonzero(conf & (np.arange(m)[:, None] < np.arange(m)[None, :]))
    assert (wave[i] < wave[j]).all(), "wave order violates slot order"


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_device_conflicts_match_host(data):
    """The executor's on-device conflict matrix == the planner's host one."""
    links, nhops = data.draw(step_routes())
    m = links.shape[0]
    valid = np.ones((m,), bool)
    dev = np.asarray(replay._conflicts(
        np.asarray(links), np.asarray(nhops), valid))
    np.testing.assert_array_equal(dev, P.step_conflicts(links, nhops))


def test_wave_width_counterexamples():
    """Pinned cases: the order-preserving recurrence is NOT graph
    coloring — a conflict path goes fully serial (width > maxdeg+1), an
    independent pairing pipelines at width 2, and disjoint routes
    collapse to one wave."""
    # path a-b, b-c, c-d: each message waits on its predecessor, so every
    # edge forces a new wave — width 4 > chromatic 2, > maxdeg+1 == 3
    conf = P.step_conflicts(
        np.asarray([[0, 1], [1, 2], [2, 3], [3, 4]]),
        np.asarray([2, 2, 2, 2]))
    assert int(P.wave_assign(conf).max()) == 4
    assert int(P.wave_assign(conf[::-1][:, ::-1]).max()) == 4
    # two independent conflicting pairs interleave: width 2
    conf_p = P.step_conflicts(
        np.asarray([[0], [0], [1], [1]]), np.asarray([1, 1, 1, 1]))
    np.testing.assert_array_equal(P.wave_assign(conf_p), [1, 2, 1, 2])
    # disjoint links: single wave
    conf_d = P.step_conflicts(
        np.asarray([[0], [1], [2], [3]]), np.asarray([1, 1, 1, 1]))
    assert int(P.wave_assign(conf_d).max()) == 1


def test_plan_wave_metadata():
    """Segment wave/live metadata drives the executor's mode choice."""
    topo = TOPOS["megafly"]
    nodes = np.arange(8, dtype=np.int64)
    tr = Trace(nodes=nodes)
    tr.messages([[0, 1, 512]])                       # 1 msg: width 1
    tr.messages([[int(a), int(b), 512] for a in range(8) for b in range(8)
                 if a != b], barrier=True)           # alltoall: wide step
    plan = P.compile_plan(tr, topo)
    caps = {s.cap for s in plan.segments}
    assert all(c > 0 for c in caps)
    seg_small = plan.segments[0]
    assert seg_small.host_wave is not None
    ww = [s.wave_width for s in plan.segments]
    assert max(ww) >= 2                              # conflicts exist
    assert all(1 <= w <= s.cap
               for w, s in zip(ww, plan.segments) if s.cap)
    for s in plan.segments:
        if not s.cap:
            continue
        # the prefix executor's trip counts ride in the device arrays and
        # agree with the host metadata the cost model reads
        np.testing.assert_array_equal(np.asarray(s.xs["live"]),
                                      s.host_live)
        assert 0.0 < s.mean_live <= s.cap
        assert 1.0 <= s.mean_wave <= s.wave_width
        # cost model: mostly-padding steps must never keep the full scan
        costs = replay.phase_costs(s, Policy(kind="fixed", t_pdt=1e-5))
        assert set(costs) == {"scan", "prefix", "chain"}
        if s.mean_live * 4 <= s.cap:
            assert min(costs, key=costs.get) != "scan"
    # needs_sort flags steps with >1 live messages
    assert any(s.needs_sort for s in plan.segments)
    # single-message-per-step segment: sort skipped
    tr2 = Trace(nodes=nodes)
    tr2.messages([[0, 1, 512]])
    tr2.messages([[2, 3, 512]], barrier=True)
    plan2 = P.compile_plan(tr2, topo)
    assert all(not s.needs_sort for s in plan2.segments if s.cap)
    assert all(s.wave_width <= 1 for s in plan2.segments if s.cap)
    # adaptive kinds never get the chained lowering offered
    costs = replay.phase_costs(plan.segments[0],
                               Policy(kind="perfbound", bound=0.01))
    assert "chain" not in costs


# ---------------------------------------------------------------------------
# Equivalence: wavefront replay == step-loop reference, bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo_name", list(TOPOS))
@pytest.mark.parametrize("kind", list(POLICIES))
@settings(max_examples=2, deadline=None)
@given(data=st.data())
def test_wavefront_replay_bit_identical(topo_name, kind, data):
    topo = TOPOS[topo_name]
    tr = data.draw(traces(topo.n_nodes))
    pol = POLICIES[kind]
    with replay.wavefront_mode("off"):
        serial, _ = S.simulate_trace(tr, topo, pol, PM)
    # force BOTH plan-scheduled lowerings — the heuristic modes pick
    # between these, so pinning each pins all of on/auto too
    for mode in ("prefix", "chain"):
        with replay.wavefront_mode(mode):
            got, _ = S.simulate_trace(tr, topo, pol, PM)
        # the new invariant: the lowering reorders NOTHING numerically
        _assert_bit_identical(got, serial, f"{topo_name}/{kind}/{mode}")
    # and the oracle contract the serial path already carries
    want, _ = S.simulate_trace_reference(tr, topo, pol, PM)
    _assert_results_match(got, want, f"{topo_name}/{kind}")


@settings(max_examples=2, deadline=None)
@given(data=st.data())
def test_wavefront_modes_agree(data):
    """Every mode produces the same bits (mode is perf-only), including
    the heuristic ones, for an adaptive kind (fallback wave loop)."""
    topo = TOPOS["fattree"]
    tr = data.draw(traces(topo.n_nodes))
    pol = POLICIES["perfbound_dual"]
    outs = {}
    for mode in replay.WAVEFRONT_MODES:
        with replay.wavefront_mode(mode):
            outs[mode], _ = S.simulate_trace(tr, topo, pol, PM)
    for mode in replay.WAVEFRONT_MODES:
        _assert_bit_identical(outs[mode], outs["off"], f"{mode}-vs-off")


def test_wavefront_multi_trace_grid():
    """The (T, B) PlanBatch path rides the same wavefront programs.

    The B lanes must share ONE static group (``canonical_proto`` comes
    from lane 0), so vary the fixed kind's timer instead of the kind."""
    topo = TOPOS["megafly"]
    pols = [Policy(kind="fixed", t_pdt=t) for t in (2e-6, 5e-6, 2e-5)]
    trs = []
    for r in (1, 3):
        nodes = np.arange(10, dtype=np.int64)
        tr = Trace(nodes=nodes, name=f"t{r}")
        tr.compute(1e-4)
        tr.messages([[int(i), int((i + r) % 10), 2048] for i in range(10)],
                    barrier=True)
        trs.append(tr)
    plans = [P.compile_plan(t, topo) for t in trs]
    batch = P.stack_plans(plans)
    with replay.wavefront_mode("on"):
        _, t_end, lat_sum, lat_max = replay.replay_plans(batch, pols, PM)
    for ti, tr in enumerate(trs):
        for bi, pol in enumerate(pols):
            with replay.wavefront_mode("off"):
                want, _ = S.simulate_trace(tr, topo, pol, PM)
            w = want.as_dict()
            assert t_end[ti, bi] == w["makespan"]
            assert lat_max[ti, bi] == w["max_latency"]


# ---------------------------------------------------------------------------
# Device residency: warm wavefront replay = 0 compiles, 0 transfers
# ---------------------------------------------------------------------------


def test_warm_wavefront_replay_is_device_resident():
    topo = TOPOS["megafly"]
    nodes = np.arange(12, dtype=np.int64)
    tr = Trace(nodes=nodes)
    for r in range(3):
        tr.compute(1e-4)
        tr.messages([[int(i), int((i + 1 + r) % 12) , 4096]
                     for i in range(12)], barrier=(r == 2))
    pol = Policy(kind="perfbound", bound=0.01)
    plan = P.compile_plan(tr, topo)

    with replay.wavefront_mode("on"):
        proto, params, carry = replay.init_lanes([pol], plan)
        out = replay.run_segments(plan, proto, params, PM, carry)  # cold
        warm_t_end = float(out[1][0])

        proto, params, carry = replay.init_lanes([pol], plan)
        with count_compiles() as cc, jax.transfer_guard("disallow"):
            out = replay.run_segments(plan, proto, params, PM, carry)
        assert cc.count == 0, "warm wavefront replay recompiled"
        t_end = float(out[1][0])
        assert t_end == warm_t_end > 0.0
