"""Plan memory audit + ragged repacking: bucket edge cases, byte
accounting, and bit-identity of repacked plans vs the pow2 layout and the
serial oracle."""
import numpy as np
import pytest

from repro.analysis import plan_memory as PMEM
from repro.core import replay
from repro.core import simulator as S
from repro.core.eee import Policy, PowerModel
from repro.scenarios.spec import build_trace
from repro.scenarios.suite import resolve
from repro.topology.megafly import small_topology
from repro.traffic.generators import small_apps
from repro.traffic.plan import (
    bucket_cap, compile_plan, group_stackable, plan_cache_clear,
    plan_cache_info, plan_nbytes, plan_shape_key, ragged_cap, repack_plans,
    stack_plans, stack_plans_cached, step_bucket)
from repro.traffic.trace import Trace

PM = PowerModel()
TINY = small_topology(n_groups=3, leaves=2, spines=2, nodes_per_leaf=2)

POLS = [Policy(kind="fixed", t_pdt=1e-5, sleep_state="deep_sleep"),
        Policy(kind="perfbound", bound=0.01, sleep_state="deep_sleep"),
        Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
               sleep_state="fast_wake", deep_state="deep_sleep")]


# ---------------------------------------------------------------------------
# Bucket edge cases (satellite: M=0 / S=1 regressions)
# ---------------------------------------------------------------------------


def test_bucket_cap_zero_one_edges():
    # with bucket_min=1, M<=1 needs exactly ONE slot (the historical
    # max(M-1, 1) rounded both up to a 2-slot bucket)
    assert bucket_cap(0, bucket_min=1) == 1
    assert bucket_cap(1, bucket_min=1) == 1
    assert bucket_cap(2, bucket_min=1) == 2
    assert bucket_cap(3, bucket_min=1) == 4
    # the production floor still dominates small M
    assert bucket_cap(0) == 64
    assert bucket_cap(64) == 64
    assert bucket_cap(65) == 128


def test_step_bucket_zero_one_edges():
    assert step_bucket(0, bucket_min=1) == 1
    assert step_bucket(1, bucket_min=1) == 1
    assert step_bucket(2, bucket_min=1) == 2
    assert step_bucket(5, bucket_min=1) == 8
    # production floor
    assert step_bucket(1) == 4
    assert step_bucket(4) == 4
    assert step_bucket(5) == 8


def test_ragged_cap_ladder():
    # the {2^k, 3*2^(k-1)} ladder: 8, 12, 16, 24, 32, 48, 64, 96, 128
    assert ragged_cap(0) == 8 and ragged_cap(1) == 8 and ragged_cap(8) == 8
    assert ragged_cap(9) == 12 and ragged_cap(12) == 12
    assert ragged_cap(13) == 16 and ragged_cap(16) == 16
    assert ragged_cap(17) == 24 and ragged_cap(24) == 24
    assert ragged_cap(25) == 32
    assert ragged_cap(48) == 48 and ragged_cap(49) == 64
    assert ragged_cap(96) == 96 and ragged_cap(97) == 128
    # never exceeds the pow2 bucket, never undershoots M
    for M in range(1, 300):
        c = ragged_cap(M)
        assert M <= c <= bucket_cap(M, bucket_min=8)


# ---------------------------------------------------------------------------
# Ragged repacking: equivalence + byte reduction
# ---------------------------------------------------------------------------


def _dc_plans():
    specs = resolve(["dc-poisson", "dc-hotspot", "dc-onoff", "dc-incast"],
                    n_nodes=8)
    traces = {n: build_trace(s, TINY) for n, s in specs.items()}
    return list(traces), [compile_plan(t, TINY) for t in traces.values()]


def test_repack_keeps_one_shape_key_and_shrinks():
    names, plans = _dc_plans()
    rp = repack_plans(plans)
    assert len({plan_shape_key(p) for p in rp}) == 1
    assert sum(plan_nbytes(p) for p in rp) < sum(plan_nbytes(p)
                                                 for p in plans)
    # still stackable as ONE group
    assert len(group_stackable(rp)) == 1


def test_repack_bit_identical_to_pow2_and_serial():
    names, plans = _dc_plans()
    b0 = stack_plans(plans, names)
    b1 = stack_plans(repack_plans(plans), names)
    r0 = replay.replay_plans(b0, POLS, PM)
    r1 = replay.replay_plans(b1, POLS, PM)
    for k, a, b in zip(("t_end", "lat_sum", "lat_max"), r0[1:], r1[1:]):
        assert np.array_equal(a, b), k
    # and vs the serial oracle, summarized field by field
    specs = resolve(["dc-poisson"], n_nodes=8)
    tr = build_trace(specs["dc-poisson"], TINY)
    for pol in POLS:
        want, _ = S.simulate_trace(tr, TINY, pol, PM)
        plan = repack_plans([compile_plan(tr, TINY)])[0]
        nets, t_end, ls, lm, _ = replay.replay_plan(plan, [pol], PM)
        import jax
        got = S.summarize(jax.tree.map(lambda x: x[0], nets),
                          float(t_end[0]), plan.busy, float(ls[0]),
                          float(lm[0]), plan.n_msgs, pol, PM, TINY)
        assert got.as_dict() == want.as_dict()


def _fragmented_trace():
    """Alternating 60/70-message single steps: six 1-step pow2 segments
    (caps 64/128) that the ragged packer should merge."""
    nodes = np.arange(8, dtype=np.int64)
    tr = Trace(nodes=nodes, name="frag")
    rng = np.random.default_rng(0)
    for r in range(3):
        tr.compute(rng.uniform(1e-5, 1e-4, 8))
        tr.messages([[int(i % 8), int((i + 1) % 8), 4096]
                     for i in range(60)], barrier=False)
        tr.messages([[int(i % 8), int((i + 3) % 8), 2048]
                     for i in range(70)], barrier=(r == 2))
    return tr


def test_repack_merges_tail_fragments():
    pl = compile_plan(_fragmented_trace(), TINY)
    assert len(pl.segments) == 6
    rp = repack_plans([pl])[0]
    assert len(rp.segments) < len(pl.segments)
    assert plan_nbytes(rp) < plan_nbytes(pl)
    r0 = replay.replay_plans(stack_plans([pl]), POLS, PM)
    r1 = replay.replay_plans(stack_plans([rp]), POLS, PM)
    for k, a, b in zip(("t_end", "lat_sum", "lat_max"), r0[1:], r1[1:]):
        assert np.array_equal(a, b), k


def test_repack_identity_when_nothing_to_gain():
    # a segment already at its ragged cap and real step bucket
    nodes = np.arange(8, dtype=np.int64)
    tr = Trace(nodes=nodes, name="full")
    for _ in range(4):
        tr.messages([[int(i % 8), int((i + 1) % 8), 1024]
                     for i in range(64)], barrier=False)
    pl = compile_plan(tr, TINY)
    assert [s.cap for s in pl.segments] == [64]
    rp = repack_plans([pl])
    assert rp[0] is pl                   # returned unchanged, not rebuilt


def test_repack_reduces_worst_catalog_scenario():
    """The acceptance criterion: ragged packing reduces padded bytes on
    the worst-waste catalog scenario (app-lammps at 80 nodes)."""
    topo = small_topology()
    tr = small_apps(topo)["lammps"]
    pl = compile_plan(tr, topo)
    rp = repack_plans([pl])[0]
    assert plan_nbytes(rp) < 0.6 * plan_nbytes(pl)
    pol = POLS[0]
    r0 = replay.replay_plans(stack_plans([pl]), [pol], PM)
    r1 = replay.replay_plans(stack_plans([rp]), [pol], PM)
    assert np.array_equal(r0[2], r1[2])
    assert np.array_equal(r0[3], r1[3])


# ---------------------------------------------------------------------------
# Stack-level cache + counter surface
# ---------------------------------------------------------------------------


def test_stack_cache_counters_and_reuse():
    plan_cache_clear()
    names, plans = _dc_plans()
    b1 = stack_plans_cached(plans, names, packing="ragged")
    b2 = stack_plans_cached(plans, names, packing="ragged")
    assert b1 is b2
    b3 = stack_plans_cached(plans, names, packing="pow2")
    assert b3 is not b1
    info = plan_cache_info()
    assert info["stack_hits"] == 1 and info["stack_misses"] == 2
    assert info["stacks"] == 2
    assert info["stack_resident_bytes"] > 0
    assert info["plans"] == 4 and info["misses"] >= 4
    assert info["resident_bytes"] > 0
    plan_cache_clear()
    info = plan_cache_info()
    assert info["stacks"] == 0 and info["stack_hits"] == 0


# ---------------------------------------------------------------------------
# The audit itself
# ---------------------------------------------------------------------------


def test_audit_plan_accounting():
    names, plans = _dc_plans()
    for name, plan in zip(names, plans):
        a = PMEM.audit_plan(plan, name)
        assert a.live_bytes <= a.padded_bytes
        assert 0.0 <= a.waste < 1.0
        # dc traces are BUCKET_MIN-dominated: most slots are padding
        assert a.waste > 0.5


def test_audit_catalog_tiny():
    a = PMEM.audit_catalog(TINY, scenarios=["dc-poisson", "dc-hotspot",
                                            "dc-onoff", "dc-incast"],
                           n_nodes=8)
    assert len(a.plans) == 4
    assert a.ragged_bytes < a.pow2_bytes
    assert 0.0 < a.ragged_saving < 1.0
    assert a.worst(2)[0].waste >= a.worst(2)[1].waste
    out = PMEM.table({TINY.n_nodes: a})
    assert "ragged_saving" in out and str(TINY.n_nodes) in out
