"""Training substrate: optimizer, grad accumulation, compression, data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLM, make_pipeline
from repro.training.compression import (compression_ratio, compress_tree,
                                        decompress_tree, dequantize_int8,
                                        ef_quantize, init_error_feedback,
                                        quantize_int8)
from repro.training.loop import (cross_entropy, init_train_state,
                                 make_train_step)
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      clip_by_global_norm, global_norm,
                                      init_opt_state)

CFG = get_config("qwen2-1.5b").smoke()


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_moves_against_gradient():
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.ones((4,), jnp.float32)}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    p1, opt, gn = adamw_update(cfg, params, grads, opt)
    assert (np.asarray(p1["w"]) < 1.0).all()
    np.testing.assert_allclose(float(gn), 2.0)      # ||1,1,1,1|| = 2


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 4.0), "b": jnp.full((4,), 3.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-6)
    # under the cap: untouched
    same, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), 4.0)


def test_warmup_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10)
    from repro.training.optimizer import lr_at
    assert float(lr_at(cfg, jnp.asarray(1))) == pytest.approx(0.1)
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def test_cross_entropy_ignores_masked_labels():
    logits = jnp.zeros((1, 4, 8), jnp.float32)
    labels = jnp.array([[1, 2, -1, -1]], jnp.int32)
    ce = cross_entropy(logits, labels, 8)
    np.testing.assert_allclose(float(ce), np.log(8), rtol=1e-6)


def test_cross_entropy_perfect_prediction():
    labels = jnp.array([[3, 5]], jnp.int32)
    logits = jax.nn.one_hot(labels, 8) * 100.0
    assert float(cross_entropy(logits, labels, 8)) < 1e-6


# ---------------------------------------------------------------------------
# Gradient accumulation == large batch
# ---------------------------------------------------------------------------


def test_grad_accum_equivalence():
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, CFG.vocab_size, (4, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, CFG.vocab_size, (4, 16)),
                              jnp.int32),
    }
    s1, m1 = jax.jit(make_train_step(CFG, grad_accum=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(CFG, grad_accum=2))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)


# ---------------------------------------------------------------------------
# Gradient compression (int8 + error feedback)
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(1024,)) * 3.0, jnp.float32)
    q, s, meta = quantize_int8(x, block=128)
    deq = dequantize_int8(q, s, meta)
    err = np.abs(np.asarray(deq - x))
    # per-block bound: scale/2 = max|block|/254
    blocks = np.asarray(x).reshape(-1, 128)
    bound = np.repeat(np.abs(blocks).max(1) / 254.0, 128) + 1e-7
    assert (err <= bound).all()
    assert q.dtype == jnp.int8


def test_error_feedback_removes_bias(rng):
    """Averaging EF-quantized copies of a constant gradient over many steps
    converges to the true value (EF cancels quantization bias)."""
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, s, meta, err = ef_quantize(g, err, block=64)
        acc = acc + dequantize_int8(q, s, meta)
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g),
                               atol=5e-3)


def test_compress_tree_roundtrip(rng):
    tree = {"a": jnp.asarray(rng.normal(size=(130,)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(4, 7)), jnp.float32)}}
    ef = init_error_feedback(tree)
    payload, new_ef = compress_tree(tree, ef, block=32)
    out = decompress_tree(payload)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.05)


def test_compression_ratio_close_to_quarter():
    params = {"w": jnp.zeros((1 << 16,), jnp.float32)}
    r = compression_ratio(params, block=2048)
    assert 0.25 < r < 0.26


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_and_restartable():
    src = SyntheticLM(256, 16, 8, seed=7)
    a = src.batch_at(5)
    b = src.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_pipeline_sharding_partitions_global_batch():
    full = SyntheticLM(256, 16, 8, seed=7)
    shards = [SyntheticLM(256, 16, 8, seed=7, shard=i, num_shards=2)
              for i in range(2)]
    fb = full.batch_at(3)
    sb = [s.batch_at(3) for s in shards]
    assert sb[0]["tokens"].shape == (4, 16)
    # each shard is internally deterministic; shards differ from each other
    assert not np.array_equal(sb[0]["tokens"], sb[1]["tokens"])


def test_pipeline_markov_structure():
    """Every transition in the stream is a legal edge of the chain."""
    src = SyntheticLM(64, 32, 4, seed=1)
    b = src.batch_at(0)
    toks = b["tokens"]
    for row in toks:
        for t in range(len(row) - 1):
            assert row[t + 1] in src.next_tok[row[t]]


def test_prefetcher_yields_in_order():
    it = make_pipeline(CFG, seq_len=8, global_batch=2, prefetch=2)
    steps = [next(it)[0] for _ in range(5)]
    assert steps == [0, 1, 2, 3, 4]
    it.close()


def test_pipeline_resume_from_step():
    it = make_pipeline(CFG, seq_len=8, global_batch=2, start_step=7,
                       prefetch=2)
    step, batch = next(it)
    assert step == 7
    src = SyntheticLM(CFG.vocab_size, 8, 2, seed=0)
    np.testing.assert_array_equal(batch["tokens"], src.batch_at(7)["tokens"])
    it.close()
