"""Checkpointing, fault tolerance, straggler mitigation, elastic restore."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointManager, latest_step,
                                      restore_checkpoint, save_checkpoint)
from repro.distributed.fault import (RecoveryStats, StragglerMonitor,
                                     WorkerFailure, plan_elastic_mesh,
                                     run_with_recovery)


def _state(x=0.0):
    return {"params": {"w": jnp.full((4, 4), x, jnp.float32),
                       "b": jnp.arange(3, dtype=jnp.int32)},
            "step": jnp.asarray(int(x), jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    st = _state(3.5)
    save_checkpoint(tmp_path, st, 7, {"note": "hi"})
    out, step, meta = restore_checkpoint(tmp_path, jax.eval_shape(lambda: st))
    assert step == 7 and meta == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_and_explicit(tmp_path):
    for s in (1, 5, 9):
        save_checkpoint(tmp_path, _state(float(s)), s)
    assert latest_step(tmp_path) == 9
    out, step, _ = restore_checkpoint(tmp_path, _state())
    assert step == 9 and float(out["params"]["w"][0, 0]) == 9.0
    out, step, _ = restore_checkpoint(tmp_path, _state(), step=5)
    assert step == 5 and float(out["params"]["w"][0, 0]) == 5.0


def test_uncommitted_checkpoint_invisible(tmp_path):
    save_checkpoint(tmp_path, _state(1.0), 1)
    # fake a torn write: directory without COMMIT
    d = tmp_path / "step_000000002"
    d.mkdir()
    (d / "MANIFEST.json").write_text("{}")
    assert latest_step(tmp_path) == 1


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, _state(), 0)
    bad = {"params": {"w": jnp.zeros((2, 2)), "b": jnp.zeros(3, jnp.int32)},
           "step": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(tmp_path, bad)


def test_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in range(5):
        mgr.save_async(_state(float(s)), s)
    mgr.wait()
    mgr.save(_state(99.0), 99)  # sync save triggers gc too
    steps = [int(p.name[5:]) for p in tmp_path.iterdir()
             if p.name.startswith("step_")]
    assert len(steps) == 2 and 99 in steps


def test_elastic_restore_onto_local_mesh(tmp_path):
    """Restore with explicit shardings — the elastic-restart path."""
    from repro.launch.mesh import make_local_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    st = _state(2.0)
    save_checkpoint(tmp_path, st, 3)
    mesh = make_local_mesh()
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    out, step, _ = restore_checkpoint(tmp_path, st, shardings=sh)
    assert out["params"]["w"].sharding == sh["params"]["w"]


# ---------------------------------------------------------------------------
# Straggler monitor
# ---------------------------------------------------------------------------


def test_straggler_flagged_and_reassigned():
    mon = StragglerMonitor(4, threshold=1.5, warmup=2, cooldown=5)
    reps = []
    for step in range(6):
        times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 4.0}
        r = mon.observe(step, times)
        if r:
            reps.append(r)
    assert reps and all(r.stragglers == [3] for r in reps)
    actions = [r.reassignment for r in reps if r.reassignment]
    assert actions and actions[0][0] == 3  # slowest swaps with a fast worker


def test_straggler_cooldown_limits_actions():
    mon = StragglerMonitor(2, threshold=1.2, warmup=1, cooldown=100)
    acts = 0
    for step in range(10):
        rep = mon.observe(step, {0: 1.0, 1: 5.0})
        if rep and rep.reassignment:
            acts += 1
    assert acts == 1


def test_no_false_positives_when_uniform():
    mon = StragglerMonitor(4, warmup=1)
    for step in range(5):
        assert mon.observe(step, {w: 1.0 for w in range(4)}) is None


# ---------------------------------------------------------------------------
# Elastic mesh planning
# ---------------------------------------------------------------------------


def test_plan_elastic_mesh():
    assert plan_elastic_mesh(512, 16) == (32, 16)
    assert plan_elastic_mesh(496, 16) == (31, 16)   # one node lost
    assert plan_elastic_mesh(16, 16) == (1, 16)
    with pytest.raises(ValueError):
        plan_elastic_mesh(8, 16)


# ---------------------------------------------------------------------------
# Recovery driver
# ---------------------------------------------------------------------------


def test_run_with_recovery_replays_from_checkpoint(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    log = []

    def step_fn(state, step):
        log.append(step)
        return {"params": {"w": state["params"]["w"] + 1.0,
                           "b": state["params"]["b"]},
                "step": jnp.asarray(step + 1, jnp.int32)}

    state, stats = run_with_recovery(
        step_fn, _state(0.0), mgr, n_steps=25,
        fail_at={7: 1, 18: 3}, save_every=5)
    assert stats.failures == 2
    assert stats.restores == 2
    assert stats.wasted_steps == (7 - 5) + (18 - 15)
    # final state reflects exactly 25 effective steps
    assert float(state["params"]["w"][0, 0]) == 25.0
    assert stats.steps_run == 25 + stats.wasted_steps


def test_recovery_with_straggler_monitor(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    mon = StragglerMonitor(4, threshold=1.5, warmup=1, cooldown=3)

    def step_fn(state, step):
        return state

    def timings(step):
        return {0: 1.0, 1: 1.0, 2: 1.0, 3: 3.0}

    _, stats = run_with_recovery(step_fn, _state(), mgr, n_steps=10,
                                 monitor=mon, timings_fn=timings)
    assert stats.reassignments >= 2   # cooldown=3 over 10 steps
