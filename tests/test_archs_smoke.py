"""Per-architecture smoke tests: every assigned arch instantiates a reduced
config of the same family and runs forward + one train step + decode on CPU,
asserting shapes and finiteness (the brief's smoke-test contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, cell_is_runnable, get_config, list_archs
from repro.models import model as M
from repro.serving.serve import generate
from repro.training.loop import init_train_state, make_train_step

ARCHS = list_archs()
B, S = 2, 32


def _batch(cfg, with_labels=True, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if with_labels:
        b["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)), cfg.dtype)
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), cfg.dtype)
    return b


def test_all_archs_registered():
    assert len(ARCHS) == 10
    assert set(ARCHS) == {
        "llava-next-34b", "qwen3-moe-30b-a3b", "dbrx-132b", "zamba2-7b",
        "rwkv6-7b", "whisper-tiny", "gemma3-4b", "qwen1.5-4b", "qwen2-1.5b",
        "nemotron-4-15b"}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dimensions(arch):
    """Exact assigned dimensions (the full configs are only lowered, never
    instantiated, so validate the numbers here)."""
    want = {
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        # attn-free: 64 = internal RWKV heads (d_model / rwkv_head_dim)
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == want
    if arch == "qwen3-moe-30b-a3b":
        assert (cfg.num_experts, cfg.experts_per_token) == (128, 8)
    if arch == "dbrx-132b":
        assert (cfg.num_experts, cfg.experts_per_token) == (16, 4)
    if arch == "zamba2-7b":
        assert cfg.ssm_state == 64 and cfg.family == "hybrid"
    if arch == "rwkv6-7b":
        assert cfg.family == "ssm"
    if arch == "gemma3-4b":
        assert cfg.global_layer_every == 6 and cfg.sliding_window > 0
    if arch == "nemotron-4-15b":
        assert cfg.act == "sq_relu"
    if arch in ("qwen1.5-4b", "qwen2-1.5b"):
        assert cfg.qkv_bias


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).smoke()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    out = M.forward(state["params"], batch, cfg, mode="train")
    assert out["logits"].shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(out["logits"].astype(jnp.float32)).all())
    # padded-vocab logits masked off
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(out["logits"][..., cfg.vocab_size:].max()) < -1e20

    step = jax.jit(make_train_step(cfg))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], state2["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_matches_prefill(arch):
    """Prefill(S) then decode(1) must equal prefill(S+1)'s last logits —
    the KV-cache/state correctness contract, for every family."""
    import dataclasses
    cfg = get_config(arch).smoke()
    if cfg.num_experts:
        # lossless expert capacity: capacity-dropping legitimately differs
        # between a 1-token decode batch and a full-sequence forward
        cfg = dataclasses.replace(cfg,
                                  moe_capacity_factor=float(cfg.num_experts))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)

    def full_fwd(n):
        b = dict(_batch(cfg, with_labels=False), tokens=toks[:, :n])
        if cfg.family == "encdec":
            b["frames"] = jnp.asarray(
                np.random.default_rng(3).normal(size=(B, 8, cfg.d_model)),
                cfg.dtype)
        return b

    out = M.forward(params, full_fwd(S), cfg, mode="prefill")

    def grow(path, x):  # linear caches sized to S: make room for 1 token
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v", "k_global", "v_global"):
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, 1)
            return jnp.pad(x, pad)
        return x
    cache = jax.tree_util.tree_map_with_path(grow, out["cache"])
    logits1, cache = M.decode_step(params, cache, toks[:, S:S + 1], cfg)
    ref = M.forward(params, full_fwd(S + 1), cfg, mode="train")
    a = np.asarray(logits1[:, -1].astype(jnp.float32))
    b = np.asarray(ref["logits"][:, -1].astype(jnp.float32))
    # smoke configs run in f32; chunked paths reorder sums -> loose tol
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "zamba2-7b", "rwkv6-7b",
                                  "gemma3-4b", "whisper-tiny"])
def test_generate_runs(arch):
    cfg = get_config(arch).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.zeros((1, 8), jnp.int32)
    toks = generate(params, cfg, prompt, steps=4)
    assert toks.shape == (1, 4)
    assert int(toks.max()) < cfg.padded_vocab


def test_generate_honors_cache_len():
    """``cache_len`` pre-sizes the KV cache bucket: a bigger bucket is
    bit-inert (attention masks the unwritten tail) and a bucket too small
    for the generation is rejected instead of silently ignored."""
    cfg = get_config("qwen2-1.5b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(
        np.random.default_rng(4).integers(0, cfg.vocab_size, (1, 8)),
        jnp.int32)
    tight = generate(params, cfg, prompt, steps=4)
    bucketed = generate(params, cfg, prompt, steps=4, cache_len=32)
    assert bool((tight == bucketed).all())
    with pytest.raises(ValueError, match="cache_len"):
        generate(params, cfg, prompt, steps=4, cache_len=8)


def test_long_500k_runnability_matrix():
    """Shape-level skips follow DESIGN.md §Arch-applicability."""
    sub_quadratic = {"zamba2-7b", "rwkv6-7b", "gemma3-4b"}
    for arch in ARCHS:
        ok, reason = cell_is_runnable(get_config(arch), SHAPES["long_500k"])
        assert ok == (arch in sub_quadratic), (arch, reason)
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = cell_is_runnable(get_config(arch), SHAPES[shape])
            assert ok


def test_param_counts_scale():
    """Full-config analytic param counts are in the right ballpark."""
    approx = {
        "llava-next-34b": 34e9, "qwen3-moe-30b-a3b": 30e9,
        "dbrx-132b": 132e9, "zamba2-7b": 7e9, "rwkv6-7b": 7e9,
        "whisper-tiny": 39e6, "gemma3-4b": 4e9, "qwen1.5-4b": 4e9,
        "qwen2-1.5b": 1.5e9, "nemotron-4-15b": 15e9,
    }
    for arch, want in approx.items():
        n = get_config(arch).param_count()
        assert 0.5 * want < n < 2.2 * want, (arch, n, want)
    # MoE: active < total
    moe = get_config("qwen3-moe-30b-a3b")
    assert moe.active_param_count() < 0.2 * moe.param_count()
