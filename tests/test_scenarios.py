"""Scenario engine: catalog coverage, seeded determinism, plan-shape
stacking, and the multi-trace batched grid — equivalence with the
per-trace engines (compiled serial AND step-loop reference) plus the
program-count bound, in the style of tests/test_plan.py."""
import numpy as np
import pytest

from repro import scenarios as SC
from repro.core import simulator as S
from repro.core.eee import Policy, PowerModel
from repro.core.instrument import count_compiles
from repro.core.sweep import group_policies, sweep_policies, sweep_scenarios
from repro.scenarios.ml import derive_grid
from repro.topology.megafly import small_topology
from repro.traffic import plan as P

PM = PowerModel()
# 12-node Megafly: big enough for 8-node allocations, fast to replay
TINY = small_topology(n_groups=3, leaves=2, spines=2, nodes_per_leaf=2)

DC_NAMES = ["dc-poisson", "dc-hotspot", "dc-onoff", "dc-incast"]

GRID = {
    "fw": Policy(kind="fixed", t_pdt=1e-5, sleep_state="fast_wake"),
    "ds": Policy(kind="fixed", t_pdt=1e-4, sleep_state="deep_sleep"),
    "pb1": Policy(kind="perfbound", bound=0.01),
    "pb5": Policy(kind="perfbound", bound=0.05),
    "dual": Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                   sleep_state="fast_wake", deep_state="deep_sleep"),
    "pbd": Policy(kind="perfbound_dual", bound=0.01,
                  sleep_state="fast_wake", deep_state="deep_sleep"),
}


def _dc_traces(n_nodes=8):
    return {n: SC.build_trace(SC.get_scenario(n).scaled(n_nodes), TINY)
            for n in DC_NAMES}


# ---------------------------------------------------------------------------
# Catalog + determinism
# ---------------------------------------------------------------------------


def test_catalog_coverage():
    names = SC.list_scenarios()
    assert len(names) >= 8
    for family, n_min in (("ml", 2), ("hpc", 2), ("dc", 2)):
        assert len(SC.list_scenarios(family)) >= n_min, family
    for name in names:
        assert SC.get_scenario(name).description


def _steps_equal(a, b):
    assert len(a.steps) == len(b.steps)
    for sa, sb in zip(a.steps, b.steps):
        assert sa.barrier == sb.barrier
        for f in ("compute_nodes", "compute_secs", "msgs"):
            x, y = getattr(sa, f), getattr(sb, f)
            assert (x is None) == (y is None)
            if x is not None:
                assert np.asarray(x).dtype == np.asarray(y).dtype
                np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("name", sorted(SC.catalog()))
def test_same_seed_same_trace(name):
    """Scenario synthesis is a pure function of (spec, topology): rebuilding
    with the cache cleared reproduces every step bit-identically."""
    spec = SC.get_scenario(name).scaled(8)
    t1 = SC.build_trace(spec, TINY)
    SC.trace_cache_clear()
    t2 = SC.build_trace(spec, TINY)
    assert t1 is not t2
    np.testing.assert_array_equal(t1.nodes, t2.nodes)
    _steps_equal(t1, t2)


def test_seed_changes_stochastic_traces():
    spec = SC.get_scenario("dc-poisson").scaled(8)
    t1 = SC.build_trace(spec, TINY)
    t2 = SC.build_trace(spec.scaled(8, seed=spec.seed + 1), TINY)
    diff = any(
        (a.msgs is None) != (b.msgs is None)
        or (a.msgs is not None and (a.msgs.shape != b.msgs.shape
                                    or not np.array_equal(a.msgs, b.msgs)))
        for a, b in zip(t1.steps, t2.steps)) or len(t1.steps) != len(t2.steps)
    assert diff, "reseeding left the stochastic trace unchanged"


def test_trace_cache_identity():
    """Equal spec values share ONE trace (keeps the plan cache keyed per
    scenario); different values do not."""
    spec = SC.get_scenario("dc-onoff").scaled(8)
    t1 = SC.build_trace(spec, TINY)
    assert SC.build_trace(SC.get_scenario("dc-onoff").scaled(8), TINY) is t1
    assert SC.build_trace(spec.scaled(8, seed=99), TINY) is not t1


def test_incast_fan_in_at_flow_cap():
    """fan_in >= max_flows must not crash (background trickle clamps to
    zero, it cannot go negative) and the fan-in itself survives."""
    spec = SC.Scenario("t-incast-wide", "dc", "incast", 8, seed=7,
                       params=SC.params_of(fan_in=7, max_flows=7,
                                           windows=4))
    tr = SC.build_trace(spec, TINY)
    msg_steps = [s for s in tr.steps if s.msgs is not None]
    assert len(msg_steps) == 4
    assert all(len(s.msgs) == 7 for s in msg_steps)


def test_stochastic_degenerate_params_emit_valid_windows():
    """Satellite audit: degenerate catalog edges — rate -> 0, duty cycle
    pinned to 0/1, an all-hot skew, fan_in <= 0 — must still emit exactly
    one NON-EMPTY message step per window with src != dst (the dc-*
    plan-shape guarantee), not divide by zero or crash the samplers."""
    cases = [
        ("poisson", dict(rate=0.0), 8),
        ("poisson", dict(hot_frac=1.0), 8),
        ("poisson", dict(hot_frac=0.5), 2),     # n_hot clamps below n_nodes
        ("onoff", dict(rate_off=0.0, p_on=0.0), 8),     # duty cycle 0
        ("onoff", dict(p_on=1.0, p_stay_on=1.0), 8),    # duty cycle 1
        ("incast", dict(fan_in=0), 8),
        ("incast", dict(fan_in=0, background_rate=0.0), 8),
    ]
    for i, (builder, extra, n) in enumerate(cases):
        spec = SC.Scenario(f"t-degen-{i}", "dc", builder, n, seed=11,
                           params=SC.params_of(windows=4, **extra))
        tr = SC.build_trace(spec, TINY)
        msg_steps = [s for s in tr.steps if s.msgs is not None]
        assert len(msg_steps) == 4, (builder, extra)
        for s in msg_steps:
            m = np.asarray(s.msgs)
            assert len(m) >= 1, (builder, extra)
            assert np.all(m[:, 0] != m[:, 1]), (builder, extra)


def test_stochastic_degenerates_share_plan_shape():
    """A zero-rate window still occupies one message bucket, so degenerate
    dc variants keep stacking along the multi-trace axis."""
    specs = [SC.Scenario("t-degen-a", "dc", "poisson", 8, seed=5,
                         params=SC.params_of(rate=0.0, windows=6)),
             SC.Scenario("t-degen-b", "dc", "onoff", 8, seed=5,
                         params=SC.params_of(rate_off=0.0, p_on=0.0,
                                             windows=6))]
    plans = [P.compile_plan(SC.build_trace(s, TINY), TINY) for s in specs]
    assert len({P.plan_shape_key(p) for p in plans}) == 1


def test_stochastic_invalid_params_fail_loudly():
    """n_nodes < 2 cannot form src != dst pairs and windows < 1 would
    synthesize an empty trace — both must raise up front, not crash deep
    inside a sampler (or emit a shape-breaking trace)."""
    for builder in ("poisson", "onoff", "incast"):
        with pytest.raises(ValueError, match="n_nodes >= 2"):
            SC.build_trace(SC.Scenario("t-bad-n", "dc", builder, 1, seed=1),
                           TINY)
        with pytest.raises(ValueError, match="windows >= 1"):
            SC.build_trace(
                SC.Scenario("t-bad-w", "dc", builder, 8, seed=1,
                            params=SC.params_of(windows=0)), TINY)


def test_ml_grid_derivation():
    assert derive_grid(8) == (4, 2, 1)
    assert derive_grid(16) == (4, 2, 2)
    assert derive_grid(16, dp=2, tp=4, pp=2) == (2, 4, 2)
    with pytest.raises(AssertionError):
        derive_grid(12)
    with pytest.raises(AssertionError):
        derive_grid(16, dp=3, tp=2, pp=2)


# ---------------------------------------------------------------------------
# Plan stacking
# ---------------------------------------------------------------------------


def test_dc_family_shares_plan_shape():
    """The whole dc-* family lowers to one plan shape by construction, so
    it stacks along the multi-trace axis."""
    plans = [P.compile_plan(tr, TINY) for tr in _dc_traces().values()]
    keys = {P.plan_shape_key(p) for p in plans}
    assert len(keys) == 1
    batch = P.stack_plans(plans, names=DC_NAMES)
    assert batch.n_traces == 4 and batch.names == DC_NAMES
    [seg] = batch.segments
    assert np.asarray(seg.xs["delta"]).shape[0] == 4   # leading T axis
    assert P.group_stackable(plans) == [[0, 1, 2, 3]]


def test_stack_rejects_shape_mismatch():
    traces = _dc_traces()
    pdc = P.compile_plan(traces["dc-poisson"], TINY)
    pml = P.compile_plan(
        SC.build_trace(SC.get_scenario("ml-qwen2-1.5b").scaled(8), TINY),
        TINY)
    assert P.plan_shape_key(pdc) != P.plan_shape_key(pml)
    with pytest.raises(AssertionError, match="different shapes"):
        P.stack_plans([pdc, pml])


# ---------------------------------------------------------------------------
# Multi-trace batched grid: equivalence + program-count bound
# ---------------------------------------------------------------------------


def test_grid_matches_serial_bit_identical_and_compiles_fewer():
    """The acceptance gate: a (4 scenarios x 6 policies — incl. the dual
    ladder and adaptive-demotion kinds) grid through the batched
    multi-trace path is bit-identical to per-trace ``simulate_trace``,
    its cold compile count scales with static groups (a small per-group
    constant — NOT with scenarios x policies), and a warm identical grid
    compiles NOTHING (every program reused across stacks and lanes)."""
    traces = _dc_traces()
    n_groups = len(group_policies(GRID))
    assert n_groups == 4
    # warm the per-policy machinery (B-lane init ops, single-trace
    # programs) so the counter below sees only the grid path's programs
    sweep_policies(traces["dc-poisson"], TINY, GRID, PM)
    want = {(tn, pn): S.simulate_trace(tr, TINY, pol, PM)[0]
            for tn, tr in traces.items() for pn, pol in GRID.items()}
    with count_compiles() as cc:
        got = sweep_scenarios(traces, TINY, GRID, PM)
    for tn in traces:
        for pn in GRID:
            assert got[tn][pn].as_dict() == want[(tn, pn)].as_dict(), \
                f"{tn}/{pn} diverged from serial replay"
    # the dc stack is ONE shape: cold programs are a per-group constant
    # (runner + init + a few eager summary ops), far under the 24-cell
    # grid; order-robust, unlike a bound that leans on prior-test warmth
    assert cc.count <= 8 * n_groups, \
        f"{cc.count} compiles > 8 x {n_groups} groups"
    with count_compiles() as cc2:
        warm = sweep_scenarios(traces, TINY, GRID, PM)
    assert cc2.count == 0, f"warm grid recompiled {cc2.count} programs"
    for tn in traces:
        for pn in GRID:
            assert warm[tn][pn].as_dict() == want[(tn, pn)].as_dict()


def test_grid_matches_step_loop_reference():
    """Multi-trace batched replay against the semantic oracle (the host
    step-loop), as tests/test_plan.py does for the single-trace path."""
    names = ["dc-poisson", "dc-onoff"]
    traces = {n: SC.build_trace(SC.get_scenario(n).scaled(8), TINY)
              for n in names}
    pols = {"ds": GRID["ds"], "pb1": GRID["pb1"]}
    got = sweep_scenarios(traces, TINY, pols, PM)
    for tn, tr in traces.items():
        for pn, pol in pols.items():
            want, _ = S.simulate_trace_reference(tr, TINY, pol, PM)
            g, w = got[tn][pn].as_dict(), want.as_dict()
            for k in w:
                np.testing.assert_allclose(g[k], w[k], rtol=1e-9,
                                           atol=1e-12,
                                           err_msg=f"{tn}/{pn}.{k}")


def test_mixed_shape_grid_covers_all_cells():
    """Scenarios that do NOT share a plan shape still sweep through
    ``sweep_scenarios`` (separate stacks), matching serial results."""
    traces = {
        "dc-poisson": SC.build_trace(
            SC.get_scenario("dc-poisson").scaled(8), TINY),
        "hpc-spectral": SC.build_trace(
            SC.get_scenario("hpc-spectral").scaled(8), TINY),
    }
    pols = {"fw": GRID["fw"], "ds": GRID["ds"]}
    got = sweep_scenarios(traces, TINY, pols, PM)
    for tn, tr in traces.items():
        for pn, pol in pols.items():
            want, _ = S.simulate_trace(tr, TINY, pol, PM)
            assert got[tn][pn].as_dict() == want.as_dict(), f"{tn}/{pn}"


# ---------------------------------------------------------------------------
# Suite runner
# ---------------------------------------------------------------------------


def test_run_suite_reports_relative_to_baseline():
    res = SC.run_suite(TINY, scenarios=["dc-poisson", "dc-onoff"],
                       policies={"ds": GRID["ds"], "pb1": GRID["pb1"]},
                       n_nodes=8)
    assert set(res) == {"dc-poisson", "dc-onoff"}
    for sc, rows in res.items():
        assert set(rows) == {"baseline", "ds", "pb1"}
        assert rows["baseline"]["exec_overhead_pct"] == 0.0
        assert rows["baseline"]["energy_saved_pct"] == 0.0
        for pn in ("ds", "pb1"):
            assert rows[pn]["makespan"] >= rows["baseline"]["makespan"]
            assert 0.0 < rows[pn]["link_energy_saved_pct"] <= 100.0
    table = SC.format_table(res)
    assert "dc-poisson" in table and "baseline" in table
    rows = list(SC.table_rows(res))
    assert len(rows) == 2 * 3
    assert {"scenario", "policy", "energy_saved_pct"} <= set(rows[0])
