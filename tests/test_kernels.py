"""Per-kernel validation: Pallas (interpret mode on CPU) vs ref.py oracle,
swept over shapes, plus hypothesis property tests on kernel invariants."""
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import perfbound as pb
from repro.core.eee import Policy
from repro.kernels import ops, ref

SHAPE_SWEEP_P = [1, 3, 64, 128, 130, 257]
SHAPE_SWEEP_B = [8, 100, 128, 200, 256]


# ---------------------------------------------------------------------------
# tpdt_select
# ---------------------------------------------------------------------------


def _rand_hist(rng, P, B):
    counts = rng.integers(0, 20, (P, B)).astype(np.float32)
    # value-sums consistent with counts: mean inside the bin
    centers = (np.arange(B) + 0.5) * 1e-5
    sums = counts * centers[None, :] * rng.uniform(0.9, 1.1, (P, B))
    sums = sums.astype(np.float32)
    N = rng.uniform(0, counts.sum(1) + 5).astype(np.float32)
    total = counts.sum(1).astype(np.float32)
    return counts, sums, N, total, centers.astype(np.float32)


@pytest.mark.parametrize("P", SHAPE_SWEEP_P)
@pytest.mark.parametrize("B", [100, 200, 256])
def test_tpdt_select_matches_ref(P, B, rng):
    counts, sums, N, total, centers = _rand_hist(rng, P, B)
    kw = dict(max_tpdt=10e-3, tpdt_init=1e-3)
    got = ops.tpdt_select_op(counts, sums, N, total, centers, **kw)
    want = ref.tpdt_select_ref(
        *(jnp.asarray(a, jnp.float32)
          for a in (counts, sums, N, total, centers)), **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=0)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_tpdt_select_dtypes(dtype, rng):
    counts, sums, N, total, centers = _rand_hist(rng, 64, 200)
    kw = dict(max_tpdt=10e-3, tpdt_init=1e-3)
    got = ops.tpdt_select_op(counts.astype(dtype), sums.astype(dtype),
                             N.astype(dtype), total.astype(dtype),
                             centers.astype(dtype), **kw)
    want = ops.tpdt_select_op(counts, sums, N, total, centers, use_ref=True,
                              **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_tpdt_select_empty_history():
    """Ports with no samples predict tpdt_init; infeasible ports max_tpdt."""
    B = 200
    counts = np.zeros((2, B), np.float32)
    counts[1, B - 1] = 50.0  # one huge-bin spike, N=0 -> infeasible
    sums = counts * 1.0
    centers = (np.arange(B) + 0.5).astype(np.float32)
    N = np.zeros((2,), np.float32)
    total = counts.sum(1)
    out = np.asarray(ops.tpdt_select_op(counts, sums, N, total, centers,
                                        max_tpdt=7.0, tpdt_init=3.0))
    assert out[0] == 3.0      # no history
    assert out[1] == 7.0      # feasible nowhere (tail count 50 > N=0)


def test_tpdt_select_leftmost_feasible(rng):
    """The oracle picks the LEFTMOST bin whose tail accumulation <= N, and
    t_PDT is that bin's mean — cross-checked against a python loop."""
    counts, sums, N, total, centers = _rand_hist(rng, 32, 64)
    out = np.asarray(ops.tpdt_select_op(counts, sums, N, total, centers,
                                        max_tpdt=99.0, tpdt_init=-1.0))
    for p in range(32):
        rcum = np.cumsum(counts[p][::-1])[::-1]
        feas = np.nonzero(rcum <= N[p])[0]
        if total[p] == 0:
            assert out[p] == -1.0
        elif len(feas) == 0:
            assert out[p] == 99.0
        else:
            j = feas[0]
            want = (sums[p, j] / counts[p, j]) if counts[p, j] > 0 \
                else centers[j]
            np.testing.assert_allclose(out[p], want, rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_tpdt_select_property(data):
    """Selected t_PDT never exceeds max_tpdt when history exists, and the
    tail count at the chosen bin respects the budget N."""
    P = data.draw(st.integers(1, 40))
    B = 64
    counts = data.draw(hnp.arrays(np.float32, (P, B),
                                  elements=st.integers(0, 9).map(float)))
    N = data.draw(hnp.arrays(
        np.float32, (P,),
        elements=st.floats(0, 512, allow_nan=False, width=32)))
    centers = (np.arange(B) + 0.5).astype(np.float32)
    sums = counts * centers[None]
    total = counts.sum(1)
    out = np.asarray(ops.tpdt_select_op(counts, sums, N, total, centers,
                                        max_tpdt=1e6, tpdt_init=0.5))
    rcum = np.cumsum(counts[:, ::-1], 1)[:, ::-1]
    feasible = (rcum <= N[:, None]).any(1)
    has_hist = total > 0
    sel = has_hist & feasible
    # chosen bin's tail accumulation is within budget
    j = np.clip(np.round(out - 0.5).astype(int), 0, B - 1)
    assert (rcum[np.arange(P), j][sel] <= N[sel] + 1e-3).all()
    assert (out[~has_hist] == 0.5).all()
    assert (out[has_hist & ~feasible] == 1e6).all()


# ---------------------------------------------------------------------------
# hist_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,P", [(1, 1), (7, 3), (64, 128), (100, 130),
                                 (513, 64)])
@pytest.mark.parametrize("log_bins", [False, True])
def test_hist_update_matches_ref(E, P, log_bins, rng):
    gaps = rng.uniform(-1e-5, 5e-3, (E, P)).astype(np.float32)
    kw = dict(n_bins=200, bin_width=10e-6, log_bins=log_bins,
              log_min=1e-7, log_max=1.0)
    gc, gs = ops.hist_update_op(gaps, **kw)
    wc, ws = ops.hist_update_op(gaps, use_ref=True, **kw)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(wc), atol=0)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                               rtol=1e-5, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_hist_update_conservation(data):
    """Counts sum to the number of positive gaps; sums to their total."""
    E = data.draw(st.integers(1, 50))
    P = data.draw(st.integers(1, 20))
    gaps = data.draw(hnp.arrays(
        np.float32, (E, P),
        elements=st.floats(-0.0009765625, 0.0078125, allow_nan=False,
                           allow_subnormal=False, width=32)))
    counts, sums = ops.hist_update_op(gaps, n_bins=128, bin_width=1e-4)
    valid = gaps > 0
    np.testing.assert_allclose(np.asarray(counts).sum(1), valid.sum(0))
    np.testing.assert_allclose(np.asarray(sums).sum(1),
                               np.where(valid, gaps, 0).sum(0),
                               rtol=1e-4, atol=1e-7)


def test_hist_update_agrees_with_perfbound_binning():
    """Kernel binning == the coupled simulator's record_gaps binning."""
    pol = Policy(kind="perfbound", hist_bins=50, hist_bin_width=1e-4)
    gaps = np.array([[5e-5, 1.23e-4, 4.9e-3, 1e9]], np.float32).T  # (4,1)->
    gaps = gaps.reshape(4, 1)
    counts, _ = ops.hist_update_op(gaps, n_bins=50, bin_width=1e-4)
    want_bins = np.asarray(pb.bin_index(jnp.asarray(gaps[:, 0]), pol))
    got_nonzero = np.nonzero(np.asarray(counts)[0])[0]
    assert sorted(set(want_bins.tolist())) == sorted(got_nonzero.tolist())


# ---------------------------------------------------------------------------
# port_energy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,P", [(1, 1), (16, 64), (100, 128), (257, 130)])
def test_port_energy_matches_ref(E, P, rng):
    gaps = rng.uniform(0, 2e-3, (E, P)).astype(np.float32)
    durs = rng.uniform(0, 1e-4, (E, P)).astype(np.float32)
    durs[rng.random((E, P)) < 0.2] = 0.0  # padding rows
    tpdt = rng.uniform(0, 1e-3, (P,)).astype(np.float32)
    tail = rng.uniform(0, 1.0, (P,)).astype(np.float32)
    kw = dict(t_w=4.48e-6, t_s=2e-6)
    got = ops.port_energy_op(gaps, durs, tpdt, tail, **kw)
    want = ops.port_energy_op(gaps, durs, tpdt, tail, use_ref=True, **kw)
    for k in got:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-8, err_msg=k)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_port_energy_conservation(data):
    """wake + sleep time equals the stream's total span (every second of
    simulated time is accounted at exactly one power level)."""
    E = data.draw(st.integers(1, 30))
    P = data.draw(st.integers(1, 8))
    gaps = data.draw(hnp.arrays(np.float32, (E, P),
                                elements=st.floats(0, 0.0078125, width=32)))
    durs = data.draw(hnp.arrays(np.float32, (E, P),
                                elements=st.floats(9.5367431640625e-07, 0.0009765625, width=32)))
    tail = data.draw(hnp.arrays(np.float32, (P,),
                                elements=st.floats(0, 0.125, width=32)))
    tpdt = data.draw(hnp.arrays(np.float32, (P,),
                                elements=st.floats(0, 0.0078125, width=32)))
    t_w, t_s = 4.48e-6, 2e-6
    out = ops.port_energy_op(gaps, durs, tpdt, tail, t_w=t_w, t_s=t_s)
    span = gaps.sum(0) + durs.sum(0) + tail
    total = np.asarray(out["time_wake"]) + np.asarray(out["time_sleep"])
    # Every second of the stream is accounted at exactly one power level,
    # plus: each miss extends the port timeline by t_w (wake transition at
    # wake power, §2.3) and, when the packet lands mid down-transition
    # (gap < tpdt + t_s), by the unfinished down time tpdt + t_s - gap.
    miss = (durs > 0) & (gaps >= tpdt[None, :])
    ext = np.where(miss, np.maximum(tpdt[None, :] + t_s - gaps, 0.0),
                   0.0).sum(0)
    extra = np.asarray(out["n_wake"]) * t_w + ext
    np.testing.assert_allclose(total, span + extra, rtol=1e-4, atol=1e-6)
    assert (np.asarray(out["hits"]) + np.asarray(out["misses"])
            == (durs > 0).sum(0)).all()


def test_port_energy_extremes():
    """tpdt=0 sleeps at every opportunity; tpdt=inf never sleeps."""
    gaps = np.full((4, 2), 1e-3, np.float32)
    durs = np.full((4, 2), 1e-5, np.float32)
    tail = np.full((2,), 0.1, np.float32)
    always = ops.port_energy_op(gaps, durs, np.zeros(2, np.float32), tail,
                                t_w=4.48e-6, t_s=2e-6)
    never = ops.port_energy_op(gaps, durs,
                               np.full((2,), 1e9, np.float32), tail,
                               t_w=4.48e-6, t_s=2e-6)
    assert (np.asarray(always["n_wake"]) == 4).all()
    assert (np.asarray(never["n_wake"]) == 0).all()
    assert (np.asarray(never["time_sleep"]) == 0).all()
    span = gaps.sum(0) + durs.sum(0) + tail
    np.testing.assert_allclose(np.asarray(never["time_wake"]), span,
                               rtol=1e-5)
    assert (np.asarray(always["time_sleep"]) > 0).all()
    assert (np.asarray(always["time_wake"]) < span).all()


@pytest.mark.parametrize("E,P", [(1, 1), (16, 64), (100, 130)])
def test_port_energy_hold_matches_ref(E, P, rng):
    """The precoalesce hold-at-source row: Pallas vs ref oracle with a
    live (P,) hold operand and a dual-mode ladder engaged."""
    gaps = rng.uniform(0, 2e-3, (E, P)).astype(np.float32)
    durs = rng.uniform(0, 1e-4, (E, P)).astype(np.float32)
    durs[rng.random((E, P)) < 0.2] = 0.0
    tpdt = rng.uniform(0, 1e-3, (P,)).astype(np.float32)
    tail = rng.uniform(0, 1.0, (P,)).astype(np.float32)
    hold = rng.uniform(0, 5e-4, (P,)).astype(np.float32)
    kw = dict(t_w=4.48e-6, t_s=2e-6, t_w2=1e-4, t_s2=1e-5)
    got = ops.port_energy_op(gaps, durs, tpdt, tail, t_dst=2e-4, hold=hold,
                             **kw)
    want = ops.port_energy_op(gaps, durs, tpdt, tail, t_dst=2e-4, hold=hold,
                              use_ref=True, **kw)
    for k in got:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-8, err_msg=k)


def test_port_energy_hold_zero_is_identity(rng):
    """hold=0 and hold=None lower to the SAME program and numbers: the
    traced hold operand costs nothing when the policy kind is not
    precoalesce."""
    gaps = rng.uniform(0, 2e-3, (32, 64)).astype(np.float32)
    durs = rng.uniform(1e-6, 1e-4, (32, 64)).astype(np.float32)
    tpdt = rng.uniform(0, 1e-3, (64,)).astype(np.float32)
    tail = rng.uniform(0, 1.0, (64,)).astype(np.float32)
    kw = dict(t_w=4.48e-6, t_s=2e-6, t_w2=1e-4, t_s2=1e-5, t_dst=2e-4)
    off = ops.port_energy_op(gaps, durs, tpdt, tail, **kw)
    zero = ops.port_energy_op(gaps, durs, tpdt, tail, hold=0.0, **kw)
    for k in off:
        np.testing.assert_array_equal(np.asarray(off[k]),
                                      np.asarray(zero[k]), err_msg=k)


def test_port_energy_hold_stretches_gap_into_deep():
    """A hold grant only applies to frames that found the port asleep, and
    stretches the effective gap across the demotion threshold: with
    hold >= t_dst an asleep-found gap demotes to the deep row."""
    t_dst = 1e-4
    gaps = np.array([[5e-5, 1.5e-4]], np.float32)   # awake-hit, asleep-miss
    durs = np.full((1, 2), 1e-5, np.float32)
    tpdt = np.full((2,), 1e-4, np.float32)
    tail = np.zeros((2,), np.float32)
    kw = dict(t_w=4.48e-6, t_s=2e-6, t_w2=1e-4, t_s2=1e-5, t_dst=t_dst)
    off = ops.port_energy_op(gaps, durs, tpdt, tail, hold=0.0, **kw)
    on = ops.port_energy_op(gaps, durs, tpdt, tail, hold=t_dst, **kw)
    # port 0 never slept: the hold row must not touch it
    assert np.asarray(off["n_deep"])[0] == np.asarray(on["n_deep"])[0] == 0
    np.testing.assert_array_equal(np.asarray(off["time_wake"])[0],
                                  np.asarray(on["time_wake"])[0])
    # port 1 slept; the stretched gap crosses tpdt + t_dst and demotes
    assert np.asarray(off["n_deep"])[1] == 0
    assert np.asarray(on["n_deep"])[1] == 1
    assert np.asarray(on["time_sleep2"])[1] > 0


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Sq,H,Hkv,dh,causal,window", [
    (2, 128, 4, 2, 32, True, None),
    (1, 96, 4, 4, 16, True, None),      # ragged seq vs 32-blocks, MHA
    (2, 64, 8, 2, 32, False, None),     # non-causal (encoder)
    (1, 128, 4, 2, 32, True, 48),       # sliding window (gemma3-style)
    (1, 64, 8, 1, 16, True, None),      # MQA
])
def test_flash_attention_matches_ref(B, Sq, H, Hkv, dh, causal, window, rng):
    q = jnp.asarray(rng.normal(size=(B, Sq, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sq, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sq, Hkv, dh)), jnp.float32)
    out = ops.flash_attention_op(q, k, v, causal=causal, window=window,
                                 block_q=32, block_kv=32)
    want = ops.flash_attention_op(q, k, v, causal=causal, window=window,
                                  use_ref=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype, rng):
    q = jnp.asarray(rng.normal(size=(1, 64, 4, 32)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), dtype)
    out = ops.flash_attention_op(q, k, v, block_q=32, block_kv=32)
    want = ops.flash_attention_op(q, k, v, use_ref=True)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2)


def test_flash_attention_in_model_forward(rng):
    """attn_impl='pallas' produces the same logits as the 'jax' path."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.models import model as M
    cfg = get_config("qwen2-1.5b").smoke()
    cfg_j = dataclasses.replace(cfg, attn_impl="jax",
                                attn_direct_max_seq=1)  # force chunked
    cfg_p = dataclasses.replace(cfg, attn_impl="pallas",
                                attn_chunk_q=16, attn_chunk_kv=16)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                                   jnp.int32)}
    a = M.forward(params, batch, cfg_j, mode="train")["logits"]
    b = M.forward(params, batch, cfg_p, mode="train")["logits"]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("B,S,H,Hkv,dh,causal,window", [
    (2, 96, 4, 2, 32, True, None),
    (2, 64, 8, 2, 32, False, None),
    (1, 96, 4, 2, 32, True, 40),
    (1, 64, 8, 1, 16, True, None),
])
def test_flash_attention_backward(B, S, H, Hkv, dh, causal, window, rng):
    """custom_vjp (Pallas fwd + FA2 two-pass Pallas bwd) matches autodiff
    of the reference to f32 precision."""
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)), jnp.float32)

    def loss(fn):
        return lambda *a: jnp.sum(jnp.sin(fn(*a).astype(jnp.float32)))

    gk = jax.grad(loss(lambda *a: ops.flash_attention_op(
        *a, causal=causal, window=window, block_q=32, block_kv=32)),
        argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss(lambda *a: ops.flash_attention_op(
        *a, causal=causal, window=window, use_ref=True)),
        argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, "q k v".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"d{name}")


def test_flash_attention_train_step_end_to_end(rng):
    """A full train step through attn_impl='pallas' (kernel fwd+bwd) moves
    params and matches the pure-JAX path's loss."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.training.loop import init_train_state, make_train_step
    cfg = dataclasses.replace(get_config("qwen2-1.5b").smoke(),
                              attn_impl="pallas", attn_chunk_q=16,
                              attn_chunk_kv=16)
    cfg_j = dataclasses.replace(cfg, attn_impl="jax")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                                   jnp.int32)}
    s1, m1 = jax.jit(make_train_step(cfg))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg_j))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=1e-3)


# ---------------------------------------------------------------------------
# ssd (Mamba2 state-space dual)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 64, 3, 16, 8, 16),
    (1, 40, 2, 8, 4, 16),       # ragged chunks
    (2, 32, 4, 32, 16, 32),     # single chunk
])
def test_ssd_matches_ref(B, S, H, P, N, Q, rng):
    xs = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cc = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, H), jnp.float32)
    D = jnp.asarray(rng.normal(size=H), jnp.float32)
    yk, hk = ops.ssd_op(xs, dt, Bc, Cc, A, D, chunk=Q)
    yr, hr = ops.ssd_op(xs, dt, Bc, Cc, A, D, use_ref=True)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=1e-4, atol=1e-5)


def test_ssd_gradients(rng):
    xs = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (1, 32, 2)), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(1, 32, 4)), jnp.float32)
    Cc = jnp.asarray(rng.normal(size=(1, 32, 4)), jnp.float32)
    A = jnp.asarray([-1.0, -2.0], jnp.float32)
    D = jnp.asarray([0.5, 0.2], jnp.float32)

    def loss(fn):
        return lambda *a: jnp.sum(jnp.sin(fn(*a)[0]))

    gk = jax.grad(loss(lambda *a: ops.ssd_op_vjp(*a, chunk=16)),
                  argnums=(0, 1, 2, 3))(xs, dt, Bc, Cc, A, D)
    gr = jax.grad(loss(lambda *a: ops.ssd_op(*a, use_ref=True)),
                  argnums=(0, 1, 2, 3))(xs, dt, Bc, Cc, A, D)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_ssd_in_mamba_block(rng):
    """ssm_impl='pallas' mamba2_block matches the chunked-jax path on a
    fresh sequence, forward and train-gradients."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.models import layers as L
    cfg = get_config("zamba2-7b").smoke()
    cfg_p = dataclasses.replace(cfg, ssm_impl="pallas")
    p = L.mamba2_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 24, cfg.d_model)), jnp.float32)
    y0, (c0, h0) = L.mamba2_block(x, p, cfg)
    y1, (c1, h1) = L.mamba2_block(x, p, cfg_p)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                               rtol=2e-3, atol=2e-4)
    g0 = jax.grad(lambda x: jnp.sum(L.mamba2_block(x, p, cfg)[0]
                                    .astype(jnp.float32)))(x)
    g1 = jax.grad(lambda x: jnp.sum(L.mamba2_block(x, p, cfg_p)[0]
                                    .astype(jnp.float32)))(x)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1),
                               rtol=5e-3, atol=5e-4)
