"""Streaming power advisor (DESIGN.md §11): drift synthesis invariants,
hysteresis-controller properties, window-replay equivalence to the serial
simulator, the warm-path zero-compile contract, and the regret acceptance
gate (online strictly beats the best static policy in hindsight on a
drifting dc-* stream, within the degradation budget)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import simulator as S
from repro.core.eee import Policy, PowerModel
from repro.core.replay import wavefront_mode
from repro.core.sweep import sweep_cells
from repro.streaming import (ControllerState, DriftSpec, SwitchConfig,
                             advise_stream, decide, get_drift, list_drifts,
                             regime_path, window_rates, window_trace)
from repro.topology.megafly import small_topology

PM = PowerModel()

# The aggressive / mild / two-stage regimes the drift catalog flips
# between (same racing pool as benchmarks/bench_stream.py).
POOL = {
    "fixed-ds-1us": Policy(kind="fixed", t_pdt=1e-6,
                           sleep_state="deep_sleep"),
    "fixed-fw-100us": Policy(kind="fixed", t_pdt=1e-4,
                             sleep_state="fast_wake"),
    "dual-10us-200us": Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                              sleep_state="fast_wake",
                              deep_state="deep_sleep"),
}


@pytest.fixture(scope="module")
def tiny():
    return small_topology(n_groups=3, leaves=2, spines=2, nodes_per_leaf=2)


# ---------------------------------------------------------------------------
# Drift synthesis
# ---------------------------------------------------------------------------


def test_drift_catalog_registered():
    names = list_drifts()
    assert {"drift-dc-diurnal", "drift-dc-flash",
            "drift-dc-regimes"} <= set(names)
    with pytest.raises(KeyError, match="unknown drift"):
        get_drift("no-such-stream")
    for n in names:
        spec = get_drift(n)
        rates = window_rates(spec)
        assert rates.shape == (spec.windows, spec.steps)
        assert (rates > 0).all()
        assert regime_path(spec).shape == (spec.windows,)


def test_drift_spec_validates():
    with pytest.raises(ValueError, match="drift kind"):
        DriftSpec("x", "sawtooth")
    with pytest.raises(ValueError, match="max_flows"):
        DriftSpec("x", "diurnal", max_flows=100)
    with pytest.raises(ValueError, match="degenerate"):
        DriftSpec("x", "diurnal", windows=0)


def test_window_trace_cached_and_seeded(tiny):
    spec = get_drift("drift-dc-regimes").scaled(n_nodes=8, windows=4)
    t0 = window_trace(spec, tiny, 0)
    assert window_trace(spec, tiny, 0) is t0       # identity-stable cache
    t1 = window_trace(spec, tiny, 1)
    assert t0.name != t1.name
    # reseeding changes the draw, same seed re-synthesizes identically
    other = window_trace(spec.scaled(seed=99), tiny, 0)
    assert other.total_bytes != t0.total_bytes
    with pytest.raises(IndexError):
        window_trace(spec, tiny, 4)


def test_windows_share_one_plan_shape(tiny):
    """The tentpole invariant: every window of a stream (quiet or busy)
    lowers to the SAME compiled plan shape, so the whole stream rides one
    program per static policy group."""
    from repro.traffic.plan import compile_plan, plan_shape_key
    spec = get_drift("drift-dc-regimes").scaled(n_nodes=8, windows=6)
    keys = {plan_shape_key(compile_plan(window_trace(spec, tiny, w), tiny))
            for w in range(spec.windows)}
    assert len(keys) == 1
    # flow counts honor the one-bucket clip [2, max_flows]
    for w in range(spec.windows):
        for step in window_trace(spec, tiny, w).steps:
            if step.msgs is not None and len(step.msgs):
                assert 2 <= len(step.msgs) <= spec.max_flows


# ---------------------------------------------------------------------------
# Hysteresis controller (pure logic — property tests)
# ---------------------------------------------------------------------------


def _run_controller(tables, cfg, start):
    """Feed per-window score tables through ``decide``; return the switch
    windows."""
    state = ControllerState(incumbent=start)
    switched_at = []
    for w, scores in enumerate(tables):
        state, switched, _ = decide(state, scores, cfg)
        if switched:
            switched_at.append(w)
    return state, switched_at


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_controller_stationary_never_flaps(data):
    """Constant scores => at most ONE switch ever (onto the stationary
    winner), regardless of config."""
    names = ["a", "b", "c"]
    scores = {n: (data.draw(st.floats(0.0, 2.0)),
                  data.draw(st.floats(1.0, 100.0))) for n in names}
    cfg = SwitchConfig(budget_pct=data.draw(st.floats(0.0, 3.0)),
                       margin_pct=data.draw(st.floats(0.0, 20.0)),
                       min_dwell=data.draw(st.integers(1, 4)),
                       smooth=data.draw(st.floats(0.1, 1.0)))
    start = data.draw(st.sampled_from(names))
    state, switched_at = _run_controller([dict(scores)] * 12, cfg, start)
    assert state.switches <= 1
    # and a switch never lands on an over-budget candidate
    if state.switches:
        assert state.ewma[state.incumbent][0] <= cfg.budget_pct


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_controller_switches_bounded_by_regime_changes(data):
    """Piecewise-stationary scores: switch count <= regime changes + 1
    (the +1 is the initial correction away from a bad prior), and
    consecutive switches are >= min_dwell windows apart."""
    table_a = {"a": (0.0, 10.0), "b": (0.0, 50.0)}
    table_b = {"a": (0.0, 50.0), "b": (0.0, 10.0)}
    flips = data.draw(st.lists(st.booleans(), min_size=6, max_size=24))
    cfg = SwitchConfig(budget_pct=1.0, margin_pct=5.0,
                       min_dwell=data.draw(st.integers(1, 3)),
                       smooth=data.draw(st.floats(0.3, 1.0)))
    tables = [table_b if f else table_a for f in flips]
    changes = int(np.sum(np.asarray(flips[1:]) != np.asarray(flips[:-1])))
    state, switched_at = _run_controller(
        tables, cfg, data.draw(st.sampled_from(["a", "b"])))
    assert state.switches <= changes + 1
    for i, j in zip(switched_at, switched_at[1:]):
        assert j - i >= cfg.min_dwell


def test_controller_budget_overrides_margin():
    """An incumbent drifting out of budget is evicted even when no
    challenger beats it on energy by the margin."""
    cfg = SwitchConfig(budget_pct=0.5, margin_pct=50.0, min_dwell=1,
                       smooth=1.0)
    state = ControllerState(incumbent="agg")
    scores = {"agg": (2.0, 10.0), "mild": (0.1, 11.0)}   # mild saves LESS
    state, switched, reason = decide(state, scores, cfg)
    assert switched and reason == "over-budget"
    assert state.incumbent == "mild"


def test_controller_no_feasible_keeps_incumbent():
    cfg = SwitchConfig(budget_pct=0.1, min_dwell=1)
    state = ControllerState(incumbent="agg")
    state, switched, reason = decide(
        state, {"agg": (5.0, 10.0), "mild": (3.0, 20.0)}, cfg)
    assert not switched and reason == "no-feasible"
    assert state.incumbent == "agg"


def test_controller_rejects_unknown_incumbent():
    with pytest.raises(AssertionError, match="incumbent"):
        decide(ControllerState(incumbent="ghost"), {"a": (0.0, 1.0)},
               SwitchConfig())


# ---------------------------------------------------------------------------
# Window replay == serial simulate_trace (bit-identity)
# ---------------------------------------------------------------------------


def test_window_replay_bit_identical_to_serial(tiny):
    """The batched lanes the advisor races are the SAME numbers a serial
    ``simulate_trace`` of that window produces — exact ``==``, the sweep
    engine's equivalence contract extended to streaming windows."""
    spec = get_drift("drift-dc-diurnal").scaled(n_nodes=8, windows=2)
    trace = window_trace(spec, tiny, 1)
    lanes = dict(POOL, none=Policy(kind="none"),
                 forecast=Policy(kind="predict", t_pdt=1e-5, t_dst=2e-4,
                                 sleep_state="fast_wake",
                                 deep_state="deep_sleep",
                                 forecast_weight=0.5, forecast_margin=2.0))
    with wavefront_mode("prefix"):
        swept = sweep_cells({trace.name: trace}, tiny,
                            {trace.name: lanes}, PM)[trace.name]
        for name, pol in lanes.items():
            serial, _ = S.simulate_trace(trace, tiny, pol, PM)
            got = swept[name]
            assert got.makespan == serial.makespan, name
            assert got.link_energy == serial.link_energy, name
            assert got.total_energy == serial.total_energy, name
            assert got.mean_latency == serial.mean_latency, name


# ---------------------------------------------------------------------------
# The online loop: warm path + stationarity + the acceptance gate
# ---------------------------------------------------------------------------


def test_stream_acceptance_beats_best_static(tiny):
    """ISSUE 10 acceptance: on a drifting dc-* stream the online advisor
    saves strictly more link energy than the best single static policy in
    hindsight, stays within the degradation budget, and re-advises every
    warm window with ZERO compiles."""
    spec = get_drift("drift-dc-regimes").scaled(n_nodes=8, windows=10)
    out = advise_stream(spec, tiny, pool=POOL, budget_pct=0.1,
                        min_dwell=1, pm=PM)
    t = out["totals"]
    assert t["gain_vs_static_pct"] > 0.0           # strictly beats static
    assert t["online_saved_pct"] > t["best_static_saved_pct"]
    assert t["online_overhead_pct"] <= 0.1         # within budget
    assert out["switches"] >= 2                    # it actually adapted
    # warm-path contract: only window 0 compiles
    compiles = [r["compiles"] for r in out["timeline"]]
    assert all(c == 0 for c in compiles[1:]), compiles
    # the loop is causal: window w is served by the incumbent chosen
    # after window w-1
    for prev, row in zip(out["timeline"], out["timeline"][1:]):
        assert row["incumbent"] == prev["next_incumbent"]


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_stream_stationary_traffic_never_flaps(tiny, seed):
    """Stationary arrivals (rate_lo == rate_hi): whatever the seed's
    Poisson noise, the advisor settles and never flaps — at most one
    switch away from the initial incumbent."""
    from repro.scenarios.spec import params_of
    spec = DriftSpec("stationary", "regimes", n_nodes=8, seed=seed,
                     windows=6,
                     params=params_of(rate_lo=800.0, rate_hi=800.0))
    out = advise_stream(spec, tiny, pool=POOL, budget_pct=1.0, pm=PM)
    assert out["switches"] <= 1
    compiles = [r["compiles"] for r in out["timeline"]]
    assert all(c == 0 for c in compiles[1:]), compiles


def test_stream_timeline_shape_and_report(tiny):
    spec = get_drift("drift-dc-flash").scaled(n_nodes=8, windows=4)
    out = advise_stream(spec, tiny, pool=POOL, budget_pct=0.5, pm=PM)
    assert out["windows"] == 4 and len(out["timeline"]) == 4
    assert out["pool"] == list(POOL)
    assert set(out["static_totals"]) == set(POOL)
    for row in out["timeline"]:
        assert row["incumbent"] in POOL
        assert np.isfinite(row["rate"]) and row["rate"] > 0
    # best-static fallback: some candidate (or the baseline) always wins
    assert out["totals"]["best_static"] in (*POOL, "baseline")


def test_advise_stream_front_door(tiny):
    """The launch-layer wrapper resolves catalog names and scales."""
    from repro.launch.power_advisor import advise_stream as front
    out = front("drift-dc-regimes", budget_pct=0.1, topo=tiny, n_nodes=8,
                windows=3, pool=POOL)
    assert out["stream"] == "drift-dc-regimes" and out["windows"] == 3
