"""Sharding rules, constraint helper, and HLO collective census."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_census
from repro.configs.base import get_config
from repro.distributed import sharding as sh
from repro.distributed.ctx import constrain
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.training.loop import abstract_train_state


def _abstract_mesh(shape, names):
    """An abstract mesh with fake sizes (no devices needed for spec tests).

    jax has changed this constructor across releases: <=0.4.35 had no
    AbstractMesh, 0.4.36/0.4.37 take ``((name, size), ...)`` pairs, and
    >=0.5 takes ``(shape, names)`` like Mesh.  Probe the pair form first.
    """
    AbstractMesh = getattr(jax.sharding, "AbstractMesh", None)
    if AbstractMesh is None:  # module-level: _abstract_mesh runs at import
        pytest.skip("jax.sharding.AbstractMesh unavailable in this jax",
                    allow_module_level=True)
    try:
        return AbstractMesh(tuple(zip(names, shape)))
    except TypeError:
        return AbstractMesh(shape, names)


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _specs(arch, mesh=MESH):
    cfg = get_config(arch)
    state = abstract_train_state(cfg)
    return cfg, state, sh.param_spec_tree(state["params"], mesh)


def test_param_rules_dense():
    cfg, state, specs = _specs("qwen2-1.5b")
    assert specs["embed"] == P("model", None)
    blk = specs["blocks"]
    assert blk["attn"]["wq"] == P(None, None, "model")     # stacked layers
    assert blk["attn"]["wo"] == P(None, "model", None)
    assert blk["mlp"]["w1"] == P(None, None, "model")
    assert blk["mlp"]["w2"] == P(None, "model", None)
    assert blk["ln1"]["scale"] == P(None, None)


def test_param_rules_moe_experts_sharded():
    cfg, state, specs = _specs("qwen3-moe-30b-a3b")
    blk = specs["blocks"]
    assert blk["moe"]["we1"] == P(None, "model", None, None)  # EP over model
    assert blk["moe"]["router"] == P(None, None, None)


def test_param_rules_ssm_families():
    _, _, specs = _specs("rwkv6-7b")
    blk = specs["blocks"]
    assert specs["lm_head"] == P(None, "model")
    assert blk["tm"]["w_r"] == P(None, None, "model")
    assert blk["tm"]["w_o"] == P(None, "model", None)
    _, _, zspecs = _specs("zamba2-7b")
    assert zspecs["blocks"]["mamba"]["in_proj"] == P(None, None, "model")
    # shared attention block is NOT stacked -> no leading None
    assert zspecs["shared"]["attn"]["wq"] == P(None, "model")


def test_batch_spec_divisibility():
    assert sh.batch_spec(MESH, 256) == P(("data",))
    assert sh.batch_spec(MESH3, 256) == P(("pod", "data"))
    assert sh.batch_spec(MESH3, 8) == P()              # 8 % 32 != 0
    assert sh.batch_spec(MESH, 1) == P()


def test_cache_spec_kv_layout():
    cfg = get_config("qwen2-1.5b")
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 128, 1024))
    specs = sh.cache_spec_tree(cache, MESH, 128)
    assert specs["k"] == P(None, ("data",), "model", None, None)
    # batch=1 (long-context): shard the sequence axis over everything
    specs1 = sh.cache_spec_tree(cache, MESH, 1)
    assert specs1["k"][1] is None
    assert specs1["k"][2] is not None


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = constrain(x, "B", "M")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_constrain_applies_inside_mesh():
    mesh = make_local_mesh()  # (n,1) on CPU

    @jax.jit
    def f(x):
        return constrain(x, "B", "M") * 2

    with mesh:
        out = f(jnp.ones((len(jax.devices()), 8)))
    assert np.asarray(out).sum() == len(jax.devices()) * 8 * 2


# ---------------------------------------------------------------------------
# HLO collective census
# ---------------------------------------------------------------------------


# Real XLA post-optimization HLO formatting: column-0 headers with tuple
# params (nested parens), layout suffixes, backend_config trip counts,
# iota replica_groups, and an async -start/-done pair.
SYNTH_HLO = """\
HloModule test, is_scheduled=true, num_partitions=256

%region_sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

%body.7_spmd.clone (p.1: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ag = f32[128,256]{1,0} all-gather(f32[8,256]{1,0} %x), replica_groups=[16,16]<=[256], dimensions={0}, metadata={op_name="x"}
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %ag), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%region_sum
  ROOT %t = (s32[], f32[128,256]{1,0}) tuple(%i, %ar)
}

%cond.8_spmd (p.2: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main.9_spmd (param.0: f32[128,256]) -> f32[128,256] {
  %w = (s32[], f32[128,256]{1,0}) while(%init), condition=%cond.8_spmd, body=%body.7_spmd.clone, backend_config={"known_trip_count":{"n":"24"}}
  %rs = f32[8,256]{1,0} reduce-scatter(f32[128,256]{1,0} %gte), replica_groups=[16,16]<=[256], dimensions={0}, to_apply=%region_sum
  %cps = (f32[4,4]{1,0}, f32[4,4]{1,0}) collective-permute-start(f32[4,4]{1,0} %y), source_target_pairs={{0,1}}
  %cpd = f32[4,4]{1,0} collective-permute-done(%cps)
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%w), index=1
}
"""


def test_census_counts_ops_and_trip_counts():
    c = collective_census(SYNTH_HLO)
    assert c["n_ops"] == 4                       # -done is not an op
    assert c["while_trip_counts"] == {"body.7_spmd.clone": 24}
    ag_res = 128 * 256 * 4
    # all-gather operand = result/n, x24 loop trips
    np.testing.assert_allclose(c["per_op"]["all-gather"],
                               ag_res / 16 * 24)
    np.testing.assert_allclose(c["per_op"]["all-reduce"], ag_res * 24)
    np.testing.assert_allclose(c["per_op"]["reduce-scatter"],
                               8 * 256 * 4 * 16)
    np.testing.assert_allclose(c["per_op"]["collective-permute"], 4 * 4 * 4)
    assert c["total_bytes"] == sum(c["per_op"].values())
    # ring wire bytes: AR counts twice (n-1)/n
    assert c["wire_bytes"] > 0


def test_census_trip_count_fallback_from_condition():
    """Without backend_config, the trip count comes from the largest s32
    constant in the loop condition."""
    txt = SYNTH_HLO.replace(
        ', backend_config={"known_trip_count":{"n":"24"}}', "")
    c = collective_census(txt)
    assert c["while_trip_counts"] == {"body.7_spmd.clone": 24}


def test_census_on_real_compiled_module():
    """End-to-end: a compiled (1-device CPU) module parses without error;
    the dry-run JSONs provide the multi-device assertions."""
    txt = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile().as_text()
    c = collective_census(txt)
    assert c["n_ops"] == 0 and c["total_bytes"] == 0.0


def test_census_empty_module():
    c = collective_census("ENTRY %main () -> f32[] {\n ROOT %z = f32[] constant(0)\n}")
    assert c["n_ops"] == 0 and c["total_bytes"] == 0
