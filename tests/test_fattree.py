"""Fat-tree topology invariants + policy parity with Megafly."""
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core.eee import Policy
from repro.core.simulator import compare_policies
from repro.topology.fattree import FatTree, small_fattree
from repro.traffic.generators import alexnet


def test_counts_k4():
    t = FatTree(k=4)
    assert t.n_nodes == 16            # k^3/4
    assert t.n_switches == 16 + 4     # 4 pods x (2+2) + 4 core
    assert t.n_links == 3 * 16        # 3 * k^3/4
    assert t.n_ports == 96


def test_counts_paper_equivalent():
    from repro.topology.fattree import paper_equivalent_fattree
    t = paper_equivalent_fattree()
    assert t.n_nodes == 26 ** 3 // 4  # 4394 ~ the paper's 4160
    assert t.n_links == 3 * t.n_nodes


def _route_ok(t, s, d):
    links, dirs, nh = t.routes(np.array([s]), np.array([d]))
    links, nh = links[0], int(nh[0])
    if s == d:
        assert nh == 0
        return
    used = links[:nh]
    assert (used >= 0).all() and (used < t.n_links).all()
    assert used[0] == s and used[-1] == d      # endpoint node links
    assert len(set(used.tolist())) == nh       # minimal: no repeats
    assert (links[nh:] == -1).all()


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 53), st.integers(0, 53))
def test_route_validity_property(s, d):
    t = FatTree(k=6)
    s, d = s % t.n_nodes, d % t.n_nodes
    _route_ok(t, s, d)


def test_hop_classes():
    t = FatTree(k=4)
    assert t.hop_distance(0, 1)[0] == 2        # same edge
    assert t.hop_distance(0, 2)[0] == 4        # same pod, other edge
    assert t.hop_distance(0, 4)[0] == 6        # other pod
    assert t.hop_distance(3, 3)[0] == 0


def test_dmodk_downpath_unique():
    """Every source reaching destination d uses the SAME core link into
    d's pod (contention-free down-paths, the D-mod-k property)."""
    t = FatTree(k=4)
    d = 9
    dn_links = set()
    for s in range(t.n_nodes):
        if t.node_pod(s) == t.node_pod(d):
            continue
        links, _, nh = t.routes(np.array([s]), np.array([d]))
        dn_links.add(int(links[0, 3]))         # core -> agg link at dst pod
    assert len(dn_links) == 1


def test_policies_run_on_fattree():
    """The whole policy stack is topology-generic: a trace + PerfBound
    runs unchanged on the fat-tree (same routes() contract)."""
    t = small_fattree(k=4)
    tr = alexnet(t, n_nodes=8, iters=2)
    out = compare_policies(
        tr, t, {"pbc": Policy(kind="perfbound_correct", bound=0.01,
                              sleep_state="deep_sleep")})
    row = out["pbc"]
    assert row["link_energy_saved_pct"] > 0
    assert np.isfinite(row["latency_overhead_pct"])
    assert row["n_wake_transitions"] > 0
