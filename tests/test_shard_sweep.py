"""Sharded multi-device sweep engine: bit-identity with the vmapped
single-device engine and the serial oracle, mesh selection, placement
caching, and warm-rerun compile counts.

Multi-device cases run under forced host devices (CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and skip
gracefully on a single-device host.
"""
import jax
import numpy as np
import pytest

from repro.core import replay
from repro.core import simulator as S
from repro.core import sweep as W
from repro.core.eee import Policy, PowerModel
from repro.core.instrument import count_compiles
from repro.distributed import shard_sweep as SS
from repro.scenarios.spec import build_trace
from repro.scenarios.suite import resolve
from repro.topology.fattree import small_fattree
from repro.topology.megafly import small_topology
from repro.traffic.plan import compile_plan, stack_plans

PM = PowerModel()
TINY = small_topology(n_groups=3, leaves=2, spines=2, nodes_per_leaf=2)

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")

# >= 3 policy kinds (plus the always-on baseline riding via sweep paths)
GRID = {
    "none": Policy(kind="none"),
    "fixed-ds": Policy(kind="fixed", t_pdt=1e-4, sleep_state="deep_sleep"),
    "pb-1pct": Policy(kind="perfbound", bound=0.01,
                      sleep_state="deep_sleep"),
    "dual": Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                   sleep_state="fast_wake", deep_state="deep_sleep"),
    "predict": Policy(kind="predict", t_pdt=1e-5, t_dst=2e-4,
                      forecast_weight=0.5, forecast_margin=2.0,
                      sleep_state="fast_wake", deep_state="deep_sleep"),
}


def _dc_traces(topo):
    specs = resolve(["dc-poisson", "dc-hotspot", "dc-onoff"], n_nodes=8)
    return {n: build_trace(s, topo) for n, s in specs.items()}


def _assert_tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(jax.tree.map(np.asarray, a))
    fb = jax.tree_util.tree_leaves(jax.tree.map(np.asarray, b))
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        assert np.array_equal(x, y, equal_nan=True)


# ---------------------------------------------------------------------------
# Mesh selection
# ---------------------------------------------------------------------------


@multi_device
def test_mesh_for_minimizes_padding():
    n = jax.device_count()
    m = SS.mesh_for(n, 1000)             # T == device count, B huge
    assert m.shape["trace"] * m.shape["lane"] == n
    assert m.shape["trace"] == n         # perfect T split, no padding
    m = SS.mesh_for(1, 8 * n)
    assert m.shape["lane"] == n          # T=1 -> all lanes
    m = SS.mesh_for(3, 5)
    assert m.shape["trace"] * m.shape["lane"] == n


def test_active_mesh_gating():
    assert SS.active_mesh(4, 16) is None          # nothing installed
    with SS.use_mesh():                           # auto mode
        if jax.device_count() > 1:
            assert SS.active_mesh(4, 16) is not None
            # grid smaller than the device pool: stay single-device
            assert SS.active_mesh(1, 1) is None
        else:
            assert SS.active_mesh(4, 16) is None
    assert SS.active_mesh(4, 16) is None          # scope restored


# ---------------------------------------------------------------------------
# Bit-identity: sharded == vmapped == serial
# ---------------------------------------------------------------------------


@multi_device
@pytest.mark.parametrize("topo", [TINY, small_fattree()],
                         ids=["megafly", "fattree"])
def test_replay_plans_sharded_bit_identical(topo):
    """Uneven T (3) and B (5) force both pad paths; every output —
    including the opaque net-state pytree — must be bit-identical to the
    single-device multi-trace engine."""
    traces = _dc_traces(topo)
    plans = [compile_plan(t, topo) for t in traces.values()]
    batch = stack_plans(plans, list(traces))
    pols = [Policy(kind="fixed", t_pdt=float(t), sleep_state="deep_sleep")
            for t in np.geomspace(1e-7, 1e-2, 5)]
    ref = replay.replay_plans(batch, pols, PM)
    got = SS.replay_plans_sharded(batch, pols, PM,
                                  SS.mesh_for(batch.n_traces, len(pols)))
    for k, a, b in zip(("t_end", "lat_sum", "lat_max"), ref[1:], got[1:]):
        assert np.array_equal(a, b), k
    _assert_tree_equal(ref[0], got[0])


@multi_device
@pytest.mark.parametrize("topo", [TINY, small_fattree()],
                         ids=["megafly", "fattree"])
def test_sweep_cells_sharded_matches_serial(topo):
    """The wired path: ``sweep_cells`` under an active mesh == the
    single-device sweep == serial ``simulate_trace``, across >= 3 policy
    kinds and both topologies."""
    traces = _dc_traces(topo)
    cells = {tn: GRID for tn in traces}
    want = W.sweep_cells(traces, topo, cells, PM)
    with SS.use_mesh():
        got = W.sweep_cells(traces, topo, cells, PM)
    for tn in traces:
        for pn in GRID:
            assert got[tn][pn].as_dict() == want[tn][pn].as_dict(), \
                (tn, pn)
    # spot-check one trace against the serial oracle per policy kind
    tn = next(iter(traces))
    for pn, pol in GRID.items():
        serial, _ = S.simulate_trace(traces[tn], topo, pol, PM)
        assert got[tn][pn].as_dict() == serial.as_dict(), (tn, pn)


@multi_device
def test_sharded_ragged_matches_pow2():
    """Ragged packing + mesh simultaneously: still bit-identical."""
    traces = _dc_traces(TINY)
    cells = {tn: GRID for tn in traces}
    want = W.sweep_cells(traces, TINY, cells, PM)
    with SS.use_mesh():
        got = W.sweep_cells(traces, TINY, cells, PM, packing="ragged")
    for tn in traces:
        for pn in GRID:
            assert got[tn][pn].as_dict() == want[tn][pn].as_dict(), \
                (tn, pn)


# ---------------------------------------------------------------------------
# Warm reruns: zero compiles, cached placement
# ---------------------------------------------------------------------------


@multi_device
def test_warm_rerun_compiles_nothing():
    traces = _dc_traces(TINY)
    plans = [compile_plan(t, TINY) for t in traces.values()]
    batch = stack_plans(plans, list(traces))
    pols = [Policy(kind="fixed", t_pdt=float(t), sleep_state="deep_sleep")
            for t in np.geomspace(1e-6, 1e-3, 4)]
    mesh = SS.mesh_for(batch.n_traces, len(pols))
    cold = SS.replay_plans_sharded(batch, pols, PM, mesh)
    before = SS.placement_cache_info()
    with count_compiles() as cc:
        warm = SS.replay_plans_sharded(batch, pols, PM, mesh)
    assert cc.count == 0
    after = SS.placement_cache_info()
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]
    for a, b in zip(cold[1:], warm[1:]):
        assert np.array_equal(a, b)
