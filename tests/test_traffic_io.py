"""Trace file round-trip: saved traces replay identically."""
import numpy as np
import pytest

from repro.core.eee import Policy
from repro.core.simulator import simulate_trace
from repro.traffic.generators import small_apps
from repro.traffic.io import load_trace, save_trace
from repro.traffic.trace import Trace


@pytest.mark.parametrize("app", ["lammps", "patmos", "mlwf", "alexnet"])
def test_roundtrip_structure(tmp_path, topo, app):
    tr = small_apps(topo, n_nodes=8)[app]
    p = tmp_path / f"{app}.npz"
    save_trace(p, tr)
    tr2 = load_trace(p)
    assert tr2.name == tr.name
    np.testing.assert_array_equal(tr2.nodes, tr.nodes)
    assert tr2.n_messages == tr.n_messages
    assert tr2.total_bytes == tr.total_bytes
    live = [s for s in tr.steps
            if (s.compute_nodes is not None and len(s.compute_nodes))
            or (s.msgs is not None and len(s.msgs)) or s.barrier]
    assert len(tr2.steps) == len(live)


def test_roundtrip_simulates_identically(tmp_path, topo, pm):
    tr = small_apps(topo, n_nodes=8)["alexnet"]
    p = tmp_path / "t.npz"
    save_trace(p, tr)
    tr2 = load_trace(p)
    pol = Policy(kind="perfbound_correct", bound=0.01,
                 sleep_state="deep_sleep")
    r1, _ = simulate_trace(tr, topo, pol, pm)
    r2, _ = simulate_trace(tr2, topo, pol, pm)
    assert r1.as_dict() == r2.as_dict()


def test_barrier_only_steps(tmp_path):
    tr = Trace(nodes=np.arange(4, dtype=np.int64), name="b")
    tr.compute(1.0)
    tr.barrier()
    tr.messages([[0, 1, 64]], barrier=True)
    p = tmp_path / "b.npz"
    save_trace(p, tr)
    tr2 = load_trace(p)
    assert tr2.steps[1].barrier and tr2.steps[1].msgs is None
    assert tr2.steps[2].barrier and len(tr2.steps[2].msgs) == 1


def test_version_check(tmp_path):
    tr = Trace(nodes=np.arange(2, dtype=np.int64))
    tr.compute(1.0)
    p = tmp_path / "v.npz"
    save_trace(p, tr)
    data = dict(np.load(p, allow_pickle=False))
    data["meta"] = np.array([99], np.int64)
    np.savez(p, **data)
    with pytest.raises(ValueError, match="format"):
        load_trace(p)
