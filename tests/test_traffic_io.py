"""Trace file round-trip: saved traces replay identically."""
import numpy as np
import pytest

from repro import scenarios as SC
from repro.core.eee import Policy
from repro.core.simulator import simulate_trace, simulate_trace_reference
from repro.traffic.generators import small_apps
from repro.traffic.io import load_trace, save_trace
from repro.traffic.trace import Step, Trace


@pytest.mark.parametrize("app", ["lammps", "patmos", "mlwf", "alexnet"])
def test_roundtrip_structure(tmp_path, topo, app):
    tr = small_apps(topo, n_nodes=8)[app]
    p = tmp_path / f"{app}.npz"
    save_trace(p, tr)
    tr2 = load_trace(p)
    assert tr2.name == tr.name
    np.testing.assert_array_equal(tr2.nodes, tr.nodes)
    assert tr2.n_messages == tr.n_messages
    assert tr2.total_bytes == tr.total_bytes
    live = [s for s in tr.steps
            if (s.compute_nodes is not None and len(s.compute_nodes))
            or (s.msgs is not None and len(s.msgs)) or s.barrier]
    assert len(tr2.steps) == len(live)


def test_roundtrip_simulates_identically(tmp_path, topo, pm):
    tr = small_apps(topo, n_nodes=8)["alexnet"]
    p = tmp_path / "t.npz"
    save_trace(p, tr)
    tr2 = load_trace(p)
    pol = Policy(kind="perfbound_correct", bound=0.01,
                 sleep_state="deep_sleep")
    r1, _ = simulate_trace(tr, topo, pol, pm)
    r2, _ = simulate_trace(tr2, topo, pol, pm)
    assert r1.as_dict() == r2.as_dict()


def test_barrier_only_steps(tmp_path):
    tr = Trace(nodes=np.arange(4, dtype=np.int64), name="b")
    tr.compute(1.0)
    tr.barrier()
    tr.messages([[0, 1, 64]], barrier=True)
    p = tmp_path / "b.npz"
    save_trace(p, tr)
    tr2 = load_trace(p)
    assert tr2.steps[1].barrier and tr2.steps[1].msgs is None
    assert tr2.steps[2].barrier and len(tr2.steps[2].msgs) == 1


@pytest.mark.parametrize("name", sorted(SC.catalog()))
def test_scenario_roundtrip_structure(tmp_path, topo, name):
    """Every synthesized scenario survives save/load with bit-identical
    steps, dtypes and metadata (the builder API emits only single-phase
    steps, so nothing is split or dropped)."""
    tr = SC.build_trace(SC.get_scenario(name).scaled(8), topo)
    p = tmp_path / "s.npz"
    save_trace(p, tr)
    tr2 = load_trace(p)
    assert tr2.name == tr.name
    assert tr2.nodes.dtype == tr.nodes.dtype == np.int64
    np.testing.assert_array_equal(tr2.nodes, tr.nodes)
    assert len(tr2.steps) == len(tr.steps)
    for i, (a, b) in enumerate(zip(tr.steps, tr2.steps)):
        assert a.barrier == b.barrier, i
        for f in ("compute_nodes", "compute_secs", "msgs"):
            x, y = getattr(a, f), getattr(b, f)
            assert (x is None) == (y is None), (i, f)
            if x is not None:
                assert np.asarray(x).dtype == np.asarray(y).dtype, (i, f)
                np.testing.assert_array_equal(x, y, err_msg=f"step{i}.{f}")


@pytest.mark.parametrize("name",
                         ["ml-qwen2-1.5b", "dc-onoff", "hpc-spectral"])
def test_scenario_roundtrip_replays_identically(tmp_path, topo, pm, name):
    """Bit-identical replay stats for a loaded scenario trace — one
    representative per synthesized family."""
    tr = SC.build_trace(SC.get_scenario(name).scaled(8), topo)
    p = tmp_path / "s.npz"
    save_trace(p, tr)
    tr2 = load_trace(p)
    pol = Policy(kind="fixed", t_pdt=5e-5, sleep_state="deep_sleep")
    r1, _ = simulate_trace(tr, topo, pol, pm)
    r2, _ = simulate_trace(tr2, topo, pol, pm)
    assert r1.as_dict() == r2.as_dict()


def test_fused_step_splits_on_save(tmp_path, topo, pm):
    """A Step carrying compute AND messages (legal in the data model; the
    old encoder silently dropped its message/barrier phases) saves as
    compute-then-messages — identical replay order, nothing lost."""
    nodes = np.arange(6, dtype=np.int64)
    tr = Trace(nodes=nodes, name="fused")
    tr.steps.append(Step(compute_nodes=nodes.copy(),
                         compute_secs=np.full(6, 1e-3),
                         msgs=np.array([[0, 3, 4096], [1, 4, 512]],
                                       np.int64),
                         barrier=True))
    tr.steps.append(Step(compute_nodes=nodes.copy(),
                         compute_secs=np.full(6, 2e-3), barrier=True))
    tr.messages([[2, 5, 1024]], barrier=True)
    p = tmp_path / "f.npz"
    save_trace(p, tr)
    tr2 = load_trace(p)
    assert tr2.n_messages == tr.n_messages == 3
    assert len(tr2.steps) == 5                    # both fused steps split
    pol = Policy(kind="fixed", t_pdt=1e-5, sleep_state="fast_wake")
    r1, _ = simulate_trace_reference(tr, topo, pol, pm)
    r2, _ = simulate_trace_reference(tr2, topo, pol, pm)
    assert r1.as_dict() == r2.as_dict()


def test_version_check(tmp_path):
    tr = Trace(nodes=np.arange(2, dtype=np.int64))
    tr.compute(1.0)
    p = tmp_path / "v.npz"
    save_trace(p, tr)
    data = dict(np.load(p, allow_pickle=False))
    data["meta"] = np.array([99], np.int64)
    np.savez(p, **data)
    with pytest.raises(ValueError, match="format"):
        load_trace(p)
