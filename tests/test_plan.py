"""Plan/execute pipeline: property-style equivalence with the step-loop
reference engine (all nine policy kinds — incl. the dual-mode FSM ladder,
coalescing and the predictive FSMs — x FatTree + Megafly, including
collect_events), plan
lowering/segmentation, plan + route caches, and device-residency of the
hot loop (no transfers, no warm compiles)."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import replay
from repro.core import simulator as S
from repro.core.eee import Policy, PowerModel
from repro.core.instrument import count_compiles
from repro.core.sweep import sweep_policies
from repro.topology.fattree import small_fattree
from repro.topology.megafly import small_topology
from repro.traffic import plan as P
from repro.traffic.trace import Trace

PM = PowerModel()
TOPOS = {"megafly": small_topology(), "fattree": small_fattree(4)}

POLICIES = {
    "none": Policy(kind="none"),
    "fixed": Policy(kind="fixed", t_pdt=5e-5, sleep_state="deep_sleep"),
    "perfbound": Policy(kind="perfbound", bound=0.02,
                        sleep_state="fast_wake"),
    "perfbound_correct": Policy(kind="perfbound_correct", bound=0.01,
                                hist_mode="circular", ring_n=32),
    "dual": Policy(kind="dual", t_pdt=2e-5, t_dst=2e-4,
                   sleep_state="fast_wake", deep_state="deep_sleep"),
    "coalesce": Policy(kind="coalesce", t_pdt=2e-5, t_dst=2e-4,
                       max_delay=5e-5, max_frames=4,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
    "perfbound_dual": Policy(kind="perfbound_dual", bound=0.02,
                             sleep_state="fast_wake",
                             deep_state="deep_sleep"),
    "precoalesce": Policy(kind="precoalesce", t_pdt=2e-5, t_dst=2e-4,
                          hold_delay=5e-5, hold_frames=4,
                          sleep_state="fast_wake", deep_state="deep_sleep"),
    "predict": Policy(kind="predict", t_pdt=2e-5, t_dst=2e-4,
                      forecast_weight=0.5, forecast_margin=2.0,
                      sleep_state="fast_wake", deep_state="deep_sleep"),
}

CHECK_FIELDS = ("makespan", "mean_latency", "max_latency", "n_messages",
                "link_energy", "switch_energy", "node_energy", "total_energy",
                "asleep_frac", "deep_frac", "n_wake_transitions", "hits", "misses",
                "deep_misses")


def _assert_results_match(got, want, label=""):
    g, w = got.as_dict(), want.as_dict()
    for k in CHECK_FIELDS:
        np.testing.assert_allclose(g[k], w[k], rtol=1e-9, atol=1e-12,
                                   err_msg=f"{label}.{k}")


@st.composite
def traces(draw, n_total):
    """Random phase-structured traces: compute / message / barrier steps in
    arbitrary interleavings (incl. consecutive computes, barrier-only steps,
    and messages-with-barrier — every lowering/fusion path)."""
    n = draw(st.integers(min_value=2, max_value=8))
    ids = draw(st.lists(st.integers(0, n_total - 1), min_size=n,
                        max_size=n, unique=True))
    nodes = np.asarray(sorted(ids), np.int64)
    tr = Trace(nodes=nodes, name="prop")
    for _ in range(draw(st.integers(min_value=2, max_value=6))):
        op = draw(st.sampled_from(
            ["compute", "compute", "msgs", "msgs", "msgs_barrier",
             "barrier"]))
        if op == "compute":
            tr.compute(np.asarray(
                [draw(st.floats(1e-6, 2e-3)) for _ in range(n)]))
        elif op == "barrier":
            tr.barrier()
        else:
            m = draw(st.integers(min_value=1, max_value=10))
            msgs = [[int(nodes[draw(st.integers(0, n - 1))]),
                     int(nodes[draw(st.integers(0, n - 1))]),
                     draw(st.integers(64, 1 << 14))] for _ in range(m)]
            tr.messages(msgs, barrier=op == "msgs_barrier")
    tr.messages([[int(nodes[0]), int(nodes[-1]), 1024]], barrier=True)
    return tr


# ---------------------------------------------------------------------------
# Equivalence: compiled plan replay == step-loop reference replay
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo_name", list(TOPOS))
@pytest.mark.parametrize("kind", list(POLICIES))
@settings(max_examples=3, deadline=None)
@given(data=st.data())
def test_compiled_replay_matches_step_loop(topo_name, kind, data):
    topo = TOPOS[topo_name]
    tr = data.draw(traces(topo.n_nodes))
    pol = POLICIES[kind]
    want, _ = S.simulate_trace_reference(tr, topo, pol, PM)
    got, _ = S.simulate_trace(tr, topo, pol, PM)
    _assert_results_match(got, want, f"{topo_name}/{kind}")


@pytest.mark.parametrize("topo_name", list(TOPOS))
@settings(max_examples=3, deadline=None)
@given(data=st.data())
def test_collect_events_matches_step_loop(topo_name, data):
    topo = TOPOS[topo_name]
    tr = data.draw(traces(topo.n_nodes))
    pol = POLICIES["fixed"]
    want, ev_want = S.simulate_trace_reference(tr, topo, pol, PM,
                                               collect_events=True)
    got, ev_got = S.simulate_trace(tr, topo, pol, PM, collect_events=True)
    _assert_results_match(got, want, topo_name)
    assert len(ev_got) == len(ev_want)
    for a, b in zip(ev_want, ev_got):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=2, deadline=None)
@given(data=st.data())
def test_batched_sweep_matches_step_loop(data):
    """The batched plan executor (B policy lanes, per-lane device argsort)
    reproduces the step-loop reference for a mixed-kind grid."""
    topo = TOPOS["megafly"]
    tr = data.draw(traces(topo.n_nodes))
    grid = {
        "none": Policy(kind="none"),
        "fw": Policy(kind="fixed", t_pdt=1e-5, sleep_state="fast_wake"),
        "ds": Policy(kind="fixed", t_pdt=1e-4, sleep_state="deep_sleep"),
        "pb1": Policy(kind="perfbound", bound=0.01),
        "pb5": Policy(kind="perfbound", bound=0.05),
        "pbc": Policy(kind="perfbound_correct", bound=0.02),
        "dual": Policy(kind="dual", t_pdt=1e-5, t_dst=1e-4,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
        "coal": Policy(kind="coalesce", t_pdt=1e-5, t_dst=1e-4,
                       max_delay=2e-5, max_frames=4,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
        "pbd": Policy(kind="perfbound_dual", bound=0.02,
                      sleep_state="fast_wake", deep_state="deep_sleep"),
        "pre": Policy(kind="precoalesce", t_pdt=1e-5, t_dst=1e-4,
                      hold_delay=2e-5, hold_frames=4,
                      sleep_state="fast_wake", deep_state="deep_sleep"),
        "pred": Policy(kind="predict", t_pdt=1e-5, t_dst=1e-4,
                       forecast_weight=0.5, forecast_margin=2.0,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
    }
    out = sweep_policies(tr, topo, grid, PM)
    for name, pol in grid.items():
        want, _ = S.simulate_trace_reference(tr, topo, pol, PM)
        _assert_results_match(out[name], want, name)


# ---------------------------------------------------------------------------
# Lowering + segmentation
# ---------------------------------------------------------------------------


def test_lowering_fuses_phases():
    """compute-only fuses into the NEXT message step; a trailing barrier
    folds into the PREVIOUS plan step — one plan step, one segment."""
    tr = Trace(nodes=np.arange(4, dtype=np.int64))
    tr.compute(1e-3).messages([[0, 1, 256]]).barrier()
    plan = P.compile_plan(tr, small_topology())
    assert plan.n_steps == 1 and plan.n_message_steps == 1
    [seg] = plan.segments
    assert seg.cap == P.BUCKET_MIN
    assert bool(np.asarray(seg.xs["barrier"])[0])
    assert float(np.asarray(seg.xs["delta"]).sum()) == pytest.approx(4e-3)


def test_segmentation_by_bucket():
    """Message steps land in power-of-two buckets; a bucket change starts
    a new segment, message-less steps join the current one."""
    topo = small_topology()
    nodes = np.arange(16, dtype=np.int64)
    tr = Trace(nodes=nodes)
    small = [[int(i), int((i + 1) % 16), 512] for i in range(5)]
    big = [[int(i % 16), int((i + 7) % 16), 512] for i in range(200)]
    tr.messages(small).compute(1e-3).messages(small)
    tr.messages(big)
    tr.messages(small, barrier=True)
    plan = P.compile_plan(tr, topo)
    assert [s.cap for s in plan.segments] == [64, 256, 64]
    assert plan.n_msgs == 5 + 5 + 200 + 5
    assert P.bucket_cap(5) == 64 and P.bucket_cap(200) == 256


def test_compute_only_trace_runs():
    tr = Trace(nodes=np.arange(4, dtype=np.int64))
    tr.compute(np.array([1.0, 2.0, 0.5, 0.1])).barrier().compute(1.0)
    plan = P.compile_plan(tr, small_topology())
    assert all(s.cap == 0 for s in plan.segments)
    res, _ = S.simulate_trace(tr, small_topology(), Policy(kind="none"), PM)
    np.testing.assert_allclose(res.makespan, 3.0, rtol=1e-12)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def test_plan_cache_hits_and_invalidates():
    topo = small_topology()
    tr = Trace(nodes=np.arange(4, dtype=np.int64))
    tr.messages([[0, 1, 512]], barrier=True)
    p1 = P.compile_plan(tr, topo)
    assert P.compile_plan(tr, topo) is p1          # sweep groups share it
    assert P.compile_plan(tr, TOPOS["fattree"]) is not p1
    tr.messages([[1, 2, 512]], barrier=True)       # builder mutation
    p2 = P.compile_plan(tr, topo)
    assert p2 is not p1 and p2.n_msgs == 2


def test_route_cache_returns_shared_arrays():
    for topo in TOPOS.values():
        topo.clear_route_cache()
        src = np.arange(8, dtype=np.int64)
        dst = (src + 5) % topo.n_nodes
        a = topo.routes_cached(src, dst)
        b = topo.routes_cached(src, dst)
        assert all(x is y for x, y in zip(a, b))   # cache hit: same arrays
        for x, y in zip(a, topo.routes(src, dst)):
            np.testing.assert_array_equal(x, y)
        assert topo.route_cache_info()["entries"] >= 1


# ---------------------------------------------------------------------------
# Device residency: the hot loop neither transfers nor compiles when warm
# ---------------------------------------------------------------------------


def test_warm_replay_is_device_resident():
    topo = TOPOS["megafly"]
    nodes = np.arange(12, dtype=np.int64)
    tr = Trace(nodes=nodes)
    for r in range(3):
        tr.compute(1e-4)
        tr.messages([[int(i), int((i + 1 + r) % 12), 4096] for i in range(12)],
                    barrier=(r == 2))
    pol = Policy(kind="perfbound", bound=0.01)
    plan = P.compile_plan(tr, topo)
    pm = PM

    proto, params, carry = replay.init_lanes([pol], plan)
    out = replay.run_segments(plan, proto, params, pm, carry)  # cold warm-up
    warm_t_end = float(out[1][0])

    proto, params, carry = replay.init_lanes([pol], plan)
    with count_compiles() as cc, jax.transfer_guard("disallow"):
        out = replay.run_segments(plan, proto, params, pm, carry)
    assert cc.count == 0, "warm replay recompiled"
    t_end = float(out[1][0])                       # readback OUTSIDE guard
    assert t_end == warm_t_end > 0.0


# ---------------------------------------------------------------------------
# Wave-schedule plan metadata (DESIGN.md §10) — the planner's contract with
# the wavefront executors, over arbitrary phase-structured traces
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_plan_wave_metadata_contract(data):
    """Every message segment carries live counts and wave widths that are
    host twins of its device arrays: ``host_live`` == per-step valid
    counts (shipped as ``xs["live"]`` for the prefix executor's trip
    bound), ``host_wave`` == the width ``wave_assign`` derives from the
    step's real routes, and the derived ``needs_sort`` / ``wave_width`` /
    ``mean_live`` / ``mean_wave`` flags follow."""
    topo = TOPOS["megafly"]
    tr = data.draw(traces(topo.n_nodes))
    plan = P.compile_plan(tr, topo)
    assert any(s.cap for s in plan.segments)
    for s in plan.segments:
        if not s.cap:
            assert s.needs_sort          # conservative default, never read
            continue
        valid = np.asarray(s.xs["valid"])
        np.testing.assert_array_equal(s.host_live, valid.sum(axis=1))
        np.testing.assert_array_equal(np.asarray(s.xs["live"]), s.host_live)
        links, nhops = np.asarray(s.xs["links"]), np.asarray(s.xs["nhops"])
        for i in range(valid.shape[0]):
            m = int(s.host_live[i])
            if m == 0:
                assert s.host_wave[i] == 0
                continue
            conf = P.step_conflicts(links[i, :m], nhops[i, :m])
            assert s.host_wave[i] == int(P.wave_assign(conf).max())
            assert 1 <= s.host_wave[i] <= m
        assert s.wave_width == int(s.host_wave.max(initial=0))
        assert s.needs_sort == (int(s.host_live.max(initial=0)) > 1)
        if s.host_live.max(initial=0) > 0:
            assert 0.0 < s.mean_live <= s.cap
            assert 1.0 <= s.mean_wave <= max(s.wave_width, 1)
