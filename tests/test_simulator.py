"""Coupled network power simulator: timing/energy semantics (paper §3/§4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simulator as S
from repro.core.eee import DEEP_SLEEP, FAST_WAKE, Policy, PowerModel
from repro.traffic.generators import small_apps
from repro.traffic.trace import Trace


def _one_msg_net(topo, policy, pm, msgs_np, collect=False):
    """Run a hand-built message list through sim_chunk."""
    src, dst, nbytes, t_inj = msgs_np
    links, dirs, nhops = topo.routes(np.asarray(src), np.asarray(dst))
    msgs = S._pad_msgs(links, dirs, nhops,
                       np.asarray(t_inj, np.float64),
                       np.asarray(nbytes, np.float64))
    net = S.init_net(topo.n_links, policy)
    net, out = S.sim_chunk(net, msgs, policy, pm, topo.n_links, collect)
    return net, out


def test_latency_no_power_saving(topo, pm):
    """Baseline cut-through latency: one serialization time + per-switch
    cut-through latency for the intermediate hops."""
    pol = Policy(kind="none")
    nbytes = 1 << 20
    net, (delivery, lat) = _one_msg_net(
        topo, pol, pm, ([0], [topo.nodes_per_group + 1], [nbytes], [0.0]))
    t_ser = nbytes / pm.link_bandwidth
    want = t_ser + 4 * pm.switch_latency  # 5 hops (inter-group), cut-through
    np.testing.assert_allclose(float(lat[0]), want, rtol=1e-9)


def test_wake_penalty_applied_once_asleep(topo, pm):
    """With t_PDT=0 every hop starts asleep: latency grows by ~hops*t_w."""
    base = Policy(kind="none")
    for state in ("fast_wake", "deep_sleep"):
        pol = Policy(kind="fixed", t_pdt=0.0, sleep_state=state)
        nbytes = 4096
        args = ([0], [topo.nodes_per_group + 1], [nbytes], [1.0])
        _, (_, lat0) = _one_msg_net(topo, base, pm, args)
        _, (_, lat1) = _one_msg_net(topo, pol, pm, args)
        st = pol.state
        extra = float(lat1[0] - lat0[0])
        want = 5 * (st.t_w + pol.sync_overhead)
        np.testing.assert_allclose(extra, want, rtol=1e-9)


def test_pdt_prevents_transition_within_window(topo, pm):
    """A second packet inside t_PDT sees NO wake penalty; outside, it does."""
    t_pdt = 1e-3
    pol = Policy(kind="fixed", t_pdt=t_pdt, sleep_state="deep_sleep")
    nbytes = 4096
    t_ser = nbytes / pm.link_bandwidth
    d = topo.nodes_per_group + 1

    def lat_of(gap):
        # first packet wakes the route; second injected ``gap`` later
        _, (_, lat) = _one_msg_net(
            topo, pol, pm, ([0, 0], [d, d], [nbytes, nbytes],
                            [1.0, 1.0 + gap]))
        return float(lat[1])

    inside = lat_of(t_pdt * 0.5)
    outside = lat_of(t_pdt * 400)     # way past expiry on every hop
    base = t_ser + 4 * pm.switch_latency  # cut-through
    np.testing.assert_allclose(inside, base, rtol=1e-9)
    assert outside > base + 4 * DEEP_SLEEP.t_w


def test_energy_conservation_per_link(topo, pm):
    """After close_out every link's wake+sleep time equals the global
    simulated span (each second at exactly one power level)."""
    pol = Policy(kind="fixed", t_pdt=50e-6, sleep_state="deep_sleep")
    rng = np.random.default_rng(0)
    M = 64
    src = rng.integers(0, topo.n_nodes, M)
    dst = (src + 1 + rng.integers(0, topo.n_nodes - 1, M)) % topo.n_nodes
    t_inj = np.sort(rng.uniform(0, 5e-3, M))
    nbytes = rng.integers(256, 1 << 16, M)
    links, dirs, nhops = topo.routes(src, dst)
    msgs = S._pad_msgs(links, dirs, nhops, t_inj.astype(np.float64),
                       nbytes.astype(np.float64))
    net = S.init_net(topo.n_links, pol)
    net, (delivery, lat) = S.sim_chunk(net, msgs, pol, pm, topo.n_links)
    t_end = float(np.asarray(delivery).max()) + 1.0
    tw, ts, ts2 = S.close_out(net, t_end, pol, topo.n_links)
    total = np.asarray(tw + ts + ts2)
    t_end_eff = max(t_end, float(net["last_end"][:topo.n_links].max()))
    # misses extend a link's local timeline by t_w (+ unfinished t_s): allow
    # only overshoot, never undershoot, and bound it by n_wake*(t_w+t_s)
    over = total - t_end_eff
    assert (over > -1e-12).all()
    bound = np.asarray(net["n_wake"][:topo.n_links]) * \
        (pol.state.t_w + pol.sync_overhead + pol.state.t_s) + 1e-12
    assert (over <= bound).all()


def test_hits_plus_misses_equals_traversals(topo, pm):
    pol = Policy(kind="fixed", t_pdt=10e-6, sleep_state="fast_wake")
    rng = np.random.default_rng(1)
    M = 32
    src = rng.integers(0, topo.n_nodes, M)
    dst = (src + 7) % topo.n_nodes
    links, dirs, nhops = topo.routes(src, dst)
    msgs = S._pad_msgs(links, dirs, nhops,
                       np.sort(rng.uniform(0, 1e-3, M)).astype(np.float64),
                       np.full(M, 4096.0))
    net = S.init_net(topo.n_links, pol)
    net, _ = S.sim_chunk(net, msgs, pol, pm, topo.n_links)
    n = topo.n_links
    assert int(net["n_hit"][:n].sum() + net["n_miss"][:n].sum()) \
        == int(nhops.sum())
    assert int(net["n_miss"][:n].sum()) == int(net["n_wake"][:n].sum())


def test_deep_sleep_saves_more_than_fast_wake_when_idle(topo, pm):
    """Long-idle trace: Deep Sleep (10 % power) beats Fast Wake (40 %)."""
    nodes = np.arange(8, dtype=np.int64)
    tr = Trace(nodes=nodes, name="idle")
    tr.messages([[0, 1, 4096]])
    tr.compute(2.0)                     # 2 s of pure compute
    tr.messages([[0, 1, 4096]], barrier=True)

    res = {}
    for state in ("fast_wake", "deep_sleep"):
        pol = Policy(kind="fixed", t_pdt=1e-6, sleep_state=state)
        r, _ = S.simulate_trace(tr, topo, pol, pm)
        res[state] = r
    base, _ = S.simulate_trace(tr, topo, Policy(kind="none"), pm)
    assert res["deep_sleep"].link_energy < res["fast_wake"].link_energy
    assert res["fast_wake"].link_energy < base.link_energy
    # ~all time asleep on ~all links: savings close to the power_frac ratio
    assert res["deep_sleep"].link_energy < 0.11 * base.link_energy
    assert res["deep_sleep"].asleep_frac > 0.99


def test_dual_ladder_sits_between_single_states_when_idle(topo, pm):
    """Long-idle trace: the Fast Wake -> Deep Sleep ladder saves more than
    fast-wake-only (it demotes through the idle span) but less than
    deep-sleep-only (it pays the fast floor for t_dst first), and the deep
    row actually engages."""
    nodes = np.arange(8, dtype=np.int64)
    tr = Trace(nodes=nodes, name="idle")
    tr.messages([[0, 1, 4096]])
    tr.compute(2.0)
    tr.messages([[0, 1, 4096]], barrier=True)

    res = {}
    for name, pol in {
        "fw": Policy(kind="fixed", t_pdt=1e-6, sleep_state="fast_wake"),
        "ds": Policy(kind="fixed", t_pdt=1e-6, sleep_state="deep_sleep"),
        "dual": Policy(kind="dual", t_pdt=1e-6, t_dst=1e-2,
                       sleep_state="fast_wake", deep_state="deep_sleep"),
    }.items():
        res[name], _ = S.simulate_trace(tr, topo, pol, pm)
    assert res["dual"].deep_misses > 0
    assert res["dual"].deep_frac > 0.9           # ~all idle past t_dst
    assert res["ds"].link_energy < res["dual"].link_energy \
        < res["fw"].link_energy
    # the ladder's wake penalty is the deep row's (it wakes from deep)
    assert res["dual"].makespan >= res["fw"].makespan


def test_coalescing_defers_wake_by_max_delay(topo, pm):
    """A frame hitting a sleeping port is held exactly ``max_delay`` per
    asleep hop (first cycle: no burst history), trading that latency for
    max_delay more sleep per hop."""
    d = topo.nodes_per_group + 1                  # 5-hop inter-group route
    base = dict(t_pdt=1e-6, t_dst=10.0, sleep_state="fast_wake",
                deep_state="deep_sleep")
    dual = Policy(kind="dual", **base)
    D = 1e-4
    coal = Policy(kind="coalesce", max_delay=D, max_frames=8, **base)
    nodes = np.arange(topo.n_nodes, dtype=np.int64)
    tr = Trace(nodes=nodes, name="t")
    tr.messages([[0, d, 4096]])
    tr.compute(np.where(nodes == 0, 1.0, 0.0))
    tr.messages([[0, d, 4096]], barrier=True)
    r_dual, _ = S.simulate_trace(tr, topo, dual, pm)
    r_coal, _ = S.simulate_trace(tr, topo, coal, pm)
    np.testing.assert_allclose(r_coal.max_latency - r_dual.max_latency,
                               5 * D, rtol=1e-6)
    # the deferred span is slept through, not idled through: the extra
    # makespan costs far less than it would at full wake power (links are
    # at the fast-wake floor; sim-end boundary effects allow a margin)
    extra_full_wake = 2 * pm.port_power * topo.n_links \
        * (r_coal.makespan - r_dual.makespan)
    assert r_coal.link_energy - r_dual.link_energy < 0.5 * extra_full_wake
    assert r_coal.asleep_frac > 0.999


def test_perfbound_dual_recovers_from_never_demote(topo, pm):
    """Regression: the adaptive demotion threshold legitimately swings
    between +inf ('never demote' — short-gap history with no amortizing
    tail) and finite once a tail forms.  The deadline2 carry must survive
    that inf -> finite transition (a scatter-ADD would latch it at NaN
    and silently disable the deep row forever)."""
    pol = Policy(kind="perfbound_dual", bound=0.01, t_dst=2e-4,
                 sleep_state="fast_wake", deep_state="deep_sleep",
                 hist_bin_width=1e-3, hist_bins=60)
    nodes = np.arange(2, dtype=np.int64)
    tr = Trace(nodes=nodes, name="t")
    # gaps far below the first bin CENTER: every suffix residual is
    # negative, no bin is feasible, tdst_select returns +inf
    for _ in range(20):                  # short-gap regime: tdst -> inf
        tr.messages([[0, 1, 4096]])
        tr.compute(2e-4)
    for _ in range(20):                  # long-tail regime: tdst finite
        tr.messages([[0, 1, 4096]])
        tr.compute(50e-3)
    tr.barrier()
    r, _ = S.simulate_trace(tr, topo, pol, pm)
    assert r.deep_misses > 0, \
        "deep row never re-engaged after a 'never demote' period"
    ref, _ = S.simulate_trace_reference(tr, topo, pol, pm)
    assert r.as_dict() == ref.as_dict()


def test_coalesce_max_frames_one_disables_deferral(topo, pm):
    """max_frames=1 (a one-frame buffer) degenerates to the plain ladder."""
    base = dict(t_pdt=1e-5, t_dst=2e-4, sleep_state="fast_wake",
                deep_state="deep_sleep")
    apps = small_apps(topo, n_nodes=8)
    r_off, _ = S.simulate_trace(
        apps["lammps"], topo,
        Policy(kind="coalesce", max_delay=1e-4, max_frames=1, **base), pm)
    r_dual, _ = S.simulate_trace(apps["lammps"], topo,
                                 Policy(kind="dual", **base), pm)
    np.testing.assert_allclose(r_off.makespan, r_dual.makespan, rtol=1e-12)
    np.testing.assert_allclose(r_off.link_energy, r_dual.link_energy,
                               rtol=1e-12)


def test_makespan_includes_compute_and_barriers(topo, pm):
    nodes = np.arange(4, dtype=np.int64)
    tr = Trace(nodes=nodes, name="t")
    tr.compute(np.array([1.0, 2.0, 0.5, 0.1]))
    tr.barrier()
    tr.compute(1.0)
    r, _ = S.simulate_trace(tr, topo, Policy(kind="none"), pm)
    np.testing.assert_allclose(r.makespan, 3.0, rtol=1e-12)


def test_message_dependency_advances_dst_clock(topo, pm):
    """dst's next compute starts only after delivery (BSP semantics)."""
    nodes = np.arange(2, dtype=np.int64)
    nbytes = 50 << 20                    # 1 ms serialization per hop
    tr = Trace(nodes=nodes, name="t")
    tr.compute(np.array([0.0, 0.0]))
    tr.messages([[0, 1, nbytes]])
    tr.compute(np.array([0.0, 1.0]))
    tr.barrier()
    r, _ = S.simulate_trace(tr, topo, Policy(kind="none"), pm)
    t_ser = nbytes / 50e9
    assert r.makespan >= 1.0 + t_ser  # cut-through delivery gates node 1


def test_baseline_energy_matches_closed_form(topo, pm):
    """Policy 'none': link energy = 2 * 24 W * n_links * makespan exactly;
    node energy = min power + usage-proportional part."""
    nodes = np.arange(4, dtype=np.int64)
    tr = Trace(nodes=nodes, name="t")
    tr.compute(1.0)
    tr.messages([[0, 1, 1024]], barrier=True)
    r, _ = S.simulate_trace(tr, topo, Policy(kind="none"), pm)
    want_link = 2 * pm.port_power * topo.n_links * r.makespan
    np.testing.assert_allclose(r.link_energy, want_link, rtol=1e-9)
    want_node = (pm.node_power_min * topo.n_nodes * r.makespan
                 + (pm.node_power_max - pm.node_power_min) * 4.0)
    np.testing.assert_allclose(r.node_energy, want_node, rtol=1e-9)
    np.testing.assert_allclose(
        r.total_energy, r.link_energy + r.node_energy
        + pm.switch_power * topo.n_switches * r.makespan, rtol=1e-12)


def test_perfbound_learns_small_tpdt_for_long_gaps(topo, pm):
    """A port seeing only second-scale gaps should learn a t_PDT far below
    the gaps (power down quickly), while still hitting a degradation bound."""
    pol = Policy(kind="perfbound", bound=0.01, sleep_state="deep_sleep",
                 hist_bin_width=10e-6, tpdt_init=10e-3)
    nodes = np.arange(2, dtype=np.int64)
    tr = Trace(nodes=nodes, name="t")
    for _ in range(30):
        tr.messages([[0, 1, 4096]])
        tr.compute(0.05)                 # 50 ms gaps
    tr.barrier()
    r, _ = S.simulate_trace(tr, topo, pol, pm)
    net_tpdt = None  # final predictions live inside the sim; check effects:
    base, _ = S.simulate_trace(tr, topo, Policy(kind="none"), pm)
    # the used links slept most of the time
    assert r.asleep_frac > 0.5
    assert r.link_energy < base.link_energy


def test_compare_policies_overheads(topo, pm):
    """compare_policies: baseline rows are zero-overhead; saving <= 90 %
    of link power (deep-sleep floor is 10 %)."""
    apps = small_apps(topo, n_nodes=8)
    tr = apps["alexnet"]
    out = S.compare_policies(
        tr, topo,
        {"fixed_100us": Policy(kind="fixed", t_pdt=100e-6,
                               sleep_state="deep_sleep")},
        pm)
    assert out["baseline"]["exec_overhead_pct"] == 0.0
    row = out["fixed_100us"]
    assert row["link_energy_saved_pct"] <= 90.0 + 1e-6
    assert row["exec_overhead_pct"] >= -1e-9
    assert row["n_wake_transitions"] > 0


def test_simulator_deterministic(topo, pm):
    apps = small_apps(topo, n_nodes=8)
    pol = Policy(kind="perfbound_correct", bound=0.02,
                 sleep_state="fast_wake")
    r1, _ = S.simulate_trace(apps["lammps"], topo, pol, pm)
    r2, _ = S.simulate_trace(apps["lammps"], topo, pol, pm)
    assert r1.as_dict() == r2.as_dict()


def test_collect_events_cover_all_hops(topo, pm):
    pol = Policy(kind="none")
    nodes = np.arange(4, dtype=np.int64)
    tr = Trace(nodes=nodes, name="t")
    tr.messages([[0, 1, 4096], [1, 2, 4096]])
    tr.barrier()
    r, events = S.simulate_trace(tr, topo, pol, pm, collect_events=True)
    lp = np.concatenate([e[0] for e in events])
    ts_ = np.concatenate([e[1] for e in events])
    te_ = np.concatenate([e[2] for e in events])
    # 0->1 same leaf (2 hops) + 1->2 same leaf (2 hops)
    assert len(lp) == 4
    assert (te_ > ts_).all()
    assert (lp < topo.n_links).all()
