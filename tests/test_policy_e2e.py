"""End-to-end policy evaluation on reduced app traces: validates the
paper's qualitative claims at small scale (the full-scale numbers live in
benchmarks/ and EXPERIMENTS.md §Paper-validation)."""
import numpy as np
import pytest

from repro.core.eee import Policy, PowerModel
from repro.core.simulator import compare_policies, simulate_trace
from repro.topology.megafly import small_topology
from repro.traffic.generators import GENERATORS, small_apps


@pytest.fixture(scope="module")
def apps():
    topo = small_topology()
    return topo, small_apps(topo, n_nodes=8)


def test_patmos_execution_time_immune(apps):
    """§4.2: PATMOS touches the network only at start/end, so ANY policy
    leaves execution time essentially unchanged."""
    topo, a = apps
    out = compare_policies(
        a["patmos"], topo,
        {"harsh": Policy(kind="fixed", t_pdt=0.0, sleep_state="deep_sleep")})
    assert abs(out["harsh"]["exec_overhead_pct"]) < 0.1
    # and the links sleep essentially the whole run
    assert out["harsh"]["asleep_frac"] > 0.99
    assert out["harsh"]["link_energy_saved_pct"] > 85.0


def test_lammps_deep_sleep_worse_than_fast_wake_overhead(apps):
    """§4.1.1 Fig 7a: with aggressive t_PDT, Deep Sleep's overhead exceeds
    Fast Wake's (t_w is an order of magnitude larger)."""
    topo, a = apps
    out = compare_policies(
        a["lammps"], topo,
        {"fw": Policy(kind="fixed", t_pdt=0.0, sleep_state="fast_wake"),
         "ds": Policy(kind="fixed", t_pdt=0.0, sleep_state="deep_sleep")})
    assert out["ds"]["exec_overhead_pct"] > out["fw"]["exec_overhead_pct"]
    assert out["ds"]["latency_overhead_pct"] > out["fw"]["latency_overhead_pct"]


def test_large_tpdt_no_overhead_little_saving(apps):
    """Fig 7: t_PDT = 1 s -> negligible overhead AND negligible link saving
    on a ~2 s trace (the paper's 'barely energy savings' endpoint)."""
    topo, a = apps
    out = compare_policies(
        a["lammps"], topo,
        {"1s": Policy(kind="fixed", t_pdt=1.0, sleep_state="deep_sleep")})
    assert abs(out["1s"]["exec_overhead_pct"]) < 0.5
    assert out["1s"]["link_energy_saved_pct"] < 30.0


def test_tpdt_sweep_tradeoff_curve(apps):
    """Larger t_PDT monotonically reduces overhead while reducing savings
    (coarse trend over decades, as in Fig 7/10/13/16)."""
    topo, a = apps
    pols = {f"t{i}": Policy(kind="fixed", t_pdt=t, sleep_state="deep_sleep")
            for i, t in enumerate([0.0, 1e-4, 1e-2, 1.0])}
    out = compare_policies(a["alexnet"], topo, pols)
    oh = [out[f"t{i}"]["exec_overhead_pct"] for i in range(4)]
    sv = [out[f"t{i}"]["link_energy_saved_pct"] for i in range(4)]
    assert oh[0] >= oh[2] - 0.5 and oh[2] >= oh[3] - 0.5
    assert sv[0] >= sv[2] >= sv[3]


def test_perfbound_bounds_degradation(apps):
    """PerfBound's whole point: overhead stays within ~the bound while still
    saving energy (LAMMPS, 1 % and 5 % thresholds)."""
    topo, a = apps
    out = compare_policies(
        a["lammps"], topo,
        {"pb1": Policy(kind="perfbound", bound=0.01,
                       sleep_state="fast_wake"),
         "pb5": Policy(kind="perfbound", bound=0.05,
                       sleep_state="fast_wake")})
    for k in ("pb1", "pb5"):
        assert out[k]["exec_overhead_pct"] < 10.0
        assert out[k]["link_energy_saved_pct"] > 0.0


def test_perfbound_correct_reduces_latency_overhead(apps):
    """The paper's headline claim (§4.1.2, §4.2.2, Fig 8c/11a): PBC reduces
    latency overhead vs plain PerfBound at equal threshold."""
    topo, a = apps
    for app in ("lammps", "alexnet"):
        out = compare_policies(
            a[app], topo,
            {"pb": Policy(kind="perfbound", bound=0.01,
                          sleep_state="deep_sleep"),
             "pbc": Policy(kind="perfbound_correct", bound=0.01,
                           sleep_state="deep_sleep")})
        assert out["pbc"]["latency_overhead_pct"] \
            <= out["pb"]["latency_overhead_pct"] + 1e-6, app
        # energy sacrifice is minimal (within a few % of link energy)
        assert out["pbc"]["link_energy_saved_pct"] \
            >= out["pb"]["link_energy_saved_pct"] - 5.0, app


def test_pbc_misses_fewer_than_pb(apps):
    topo, a = apps
    out = compare_policies(
        a["mlwf"], topo,
        {"pb": Policy(kind="perfbound", bound=0.01,
                      sleep_state="deep_sleep"),
         "pbc": Policy(kind="perfbound_correct", bound=0.01,
                       sleep_state="deep_sleep")})
    pb_miss = out["pb"]["misses"] / max(out["pb"]["hits"]
                                        + out["pb"]["misses"], 1)
    pbc_miss = out["pbc"]["misses"] / max(out["pbc"]["hits"]
                                          + out["pbc"]["misses"], 1)
    assert pbc_miss <= pb_miss + 1e-9


def test_histogram_modes_all_run(apps):
    topo, a = apps
    pols = {m: Policy(kind="perfbound_correct", bound=0.02, hist_mode=m,
                      sleep_state="fast_wake", hist_clear_n=50, ring_n=50)
            for m in ("keep_all", "self_clear", "circular")}
    out = compare_policies(a["alexnet"], topo, pols)
    for m, row in out.items():
        assert np.isfinite(row["total_energy"])
        if m != "baseline":
            assert row["n_wake_transitions"] > 0


def test_generators_signatures(apps):
    """Traffic signatures match the paper's descriptions: PATMOS is
    endpoint-only; MLWF is near-continuous; AlexNet is periodic bursts."""
    topo, a = apps
    pat, mlwf = a["patmos"], a["mlwf"]
    # PATMOS: almost all wall time is one compute phase
    comp = sum(float(s.compute_secs.max()) for s in pat.steps
               if s.compute_secs is not None)
    assert comp >= 20.0
    assert pat.n_messages < 200
    # MLWF: many more message rounds per unit compute
    assert mlwf.n_messages > pat.n_messages
    # AlexNet gradient buckets: 8 layers x iters AllReduces
    assert a["alexnet"].total_bytes > 100 << 20
