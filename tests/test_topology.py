"""Megafly topology and routing invariants (paper §4 scenario)."""
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.topology.megafly import Megafly, paper_topology, small_topology


def test_paper_scenario_counts():
    """Table 5: 4160 nodes, 1040 switches, 20800 port-ends."""
    t = paper_topology()
    assert t.n_nodes == 4160
    assert t.n_switches == 1040
    assert t.n_ports == 20800
    assert t.n_groups == 65
    assert t.radix == 16
    assert t.n_global_links == 65 * 64 // 2
    assert t.n_links == 4160 + 65 * 64 + 2080


def test_global_link_bijection():
    """Every unordered group pair maps to a unique global link id."""
    t = small_topology()
    seen = set()
    for g in range(t.n_groups):
        for h in range(t.n_groups):
            if g == h:
                continue
            l = int(t.global_link(g, h))
            assert t.global_link(h, g) == l     # symmetric
            seen.add(l)
    assert len(seen) == t.n_global_links
    lo = t.n_node_links + t.n_ls_links
    assert min(seen) == lo and max(seen) == lo + t.n_global_links - 1


def test_peer_port_is_permutation():
    """Group g's 64 global ports each lead to a distinct other group."""
    t = paper_topology()
    for g in [0, 13, 64]:
        others = np.array([h for h in range(t.n_groups) if h != g])
        ports = t.peer_port(g, others)
        assert sorted(ports.tolist()) == list(range(t.n_groups - 1))


def _route_ok(t, s, d):
    links, dirs, nh = t.routes(np.array([s]), np.array([d]))
    links, nh = links[0], int(nh[0])
    if s == d:
        assert nh == 0
        return
    used = links[:nh]
    assert (used >= 0).all() and (used < t.n_links).all()
    assert (links[nh:] == -1).all()
    # first/last hop are the endpoints' node links
    assert used[0] == s
    assert used[-1] == d
    # no link repeats (minimal routing)
    assert len(set(used.tolist())) == nh


def test_route_hop_counts():
    t = small_topology()  # 5 groups x 4 leaves x 4 nodes/leaf
    npl, lpg = t.nodes_per_leaf, t.nodes_per_group
    assert t.hop_distance(0, 1)[0] == 2             # same leaf
    assert t.hop_distance(0, npl)[0] == 4           # same group, diff leaf
    assert t.hop_distance(0, lpg)[0] == 5           # inter group
    assert t.hop_distance(7, 7)[0] == 0


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 79), st.integers(0, 79))
def test_route_validity_property(s, d):
    t = small_topology()
    _route_ok(t, s, d)


def test_route_validity_paper_topology():
    t = paper_topology()
    rng = np.random.default_rng(0)
    src = rng.integers(0, t.n_nodes, 200)
    dst = rng.integers(0, t.n_nodes, 200)
    links, dirs, nh = t.routes(src, dst)
    for i in range(len(src)):
        _route_ok(t, int(src[i]), int(dst[i]))
    # hop-count classes
    gs, gd = t.node_group(src), t.node_group(dst)
    ls, ld = t.node_leaf(src), t.node_leaf(dst)
    want = np.where(src == dst, 0,
                    np.where((gs == gd) & (ls == ld), 2,
                             np.where(gs == gd, 4, 5)))
    np.testing.assert_array_equal(nh, want)


def test_inter_group_route_uses_the_unique_global_link():
    t = small_topology()
    s, d = 0, t.nodes_per_group * 2 + 5   # group 0 -> group 2
    links, dirs, nh = t.routes(np.array([s]), np.array([d]))
    assert int(nh[0]) == 5
    gl = int(t.global_link(0, 2))
    assert gl in links[0].tolist()
    # global hop direction: 0 transmits lo->hi group
    pos = links[0].tolist().index(gl)
    assert dirs[0, pos] == 0


def test_dmodk_spine_selection():
    """Intra-group up-path spine is dst % spines (D-mod-k)."""
    t = small_topology()
    d = 9   # leaf 2, spine should be 9 % 4 = 1
    links, _, nh = t.routes(np.array([0]), np.array([d]))
    up = int(links[0, 1])
    assert up == int(t.ls_link(0, 0, d % t.spines_per_group))


def test_routes_deterministic():
    t = small_topology()
    rng = np.random.default_rng(3)
    src = rng.integers(0, t.n_nodes, 64)
    dst = rng.integers(0, t.n_nodes, 64)
    a = t.routes(src, dst)
    b = t.routes(src, dst)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_direction_disambiguates_duplex():
    """A->B and B->A use the same link ids with opposite direction bits."""
    t = small_topology()
    l1, d1, n1 = t.routes(np.array([0]), np.array([1]))
    l2, d2, n2 = t.routes(np.array([1]), np.array([0]))
    assert n1[0] == n2[0] == 2
    assert set(l1[0, :2].tolist()) == set(l2[0, :2].tolist())
    # node links: up = dir 0 at the source, down = dir 1 at the destination
    assert d1[0, 0] == 0 and d1[0, 1] == 1
    assert d2[0, 0] == 0 and d2[0, 1] == 1
