"""Scenario registry: the named catalog the suite runner sweeps.

``repro.scenarios.catalog`` registers the built-in entries at package
import; user code can register more at runtime (e.g. converted VEF
captures wrapped in a builder).  Names are unique; lookups fail loudly
with the available names.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.scenarios.spec import Scenario

_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(spec: Scenario) -> Scenario:
    assert spec.name not in _REGISTRY, f"duplicate scenario {spec.name!r}"
    _REGISTRY[spec.name] = spec
    return spec


def get_scenario(name: str) -> Scenario:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_scenarios(family: Optional[str] = None) -> list:
    return sorted(n for n, s in _REGISTRY.items()
                  if family is None or s.family == family)


def catalog() -> Dict[str, Scenario]:
    """The full registry, insertion-ordered (catalog order)."""
    return dict(_REGISTRY)
