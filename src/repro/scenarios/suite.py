"""Scenario-suite runner: a named catalog x policy grid in batched replays.

``run_suite`` is the one-call evaluation loop the paper runs per
application (§4), generalized over the scenario catalog and executed on
the multi-trace batched path: every scenario's trace builds once
(``spec.build_trace`` memo), plans compile once per (trace, topology)
(plan cache), same-shape plans stack along the trace axis and each static
policy group replays the whole stack in one compiled program per segment
shape (``sweep.sweep_scenarios``).  An always-on baseline rides along in
the grid (its own static group, stacked over all traces like any other)
and every scenario's energy/degradation numbers are reported relative to
ITS OWN baseline — the paper's protocol.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Union

from repro.core.eee import Policy, PowerModel
from repro.core.simulator import relative_rows, unused_key
from repro.core.sweep import sweep_scenarios
from repro.scenarios.registry import get_scenario, list_scenarios
from repro.scenarios.spec import Scenario, build_trace

_BASELINE_POLICY = Policy(kind="none")


def default_policy_grid() -> Dict[str, Policy]:
    """A compact representative grid: both sleep states on fixed PDT, both
    single-state adaptive predictors, the three reactive dual-mode FSM
    kinds (DESIGN.md §6), and the two predictive kinds (DESIGN.md §8) —
    9 policies in 8 static groups."""
    return {
        "fixed-fw-10us": Policy(kind="fixed", t_pdt=1e-5,
                                sleep_state="fast_wake"),
        "fixed-ds-100us": Policy(kind="fixed", t_pdt=1e-4,
                                 sleep_state="deep_sleep"),
        "perfbound-1pct": Policy(kind="perfbound", bound=0.01,
                                 sleep_state="deep_sleep"),
        "pbc-1pct": Policy(kind="perfbound_correct", bound=0.01,
                           sleep_state="deep_sleep"),
        "dual-10us-200us": Policy(kind="dual", t_pdt=1e-5, t_dst=2e-4,
                                  sleep_state="fast_wake",
                                  deep_state="deep_sleep"),
        "coalesce-50us": Policy(kind="coalesce", t_pdt=1e-5, t_dst=2e-4,
                                max_delay=5e-5, max_frames=16,
                                sleep_state="fast_wake",
                                deep_state="deep_sleep"),
        "pbd-1pct": Policy(kind="perfbound_dual", bound=0.01,
                           sleep_state="fast_wake",
                           deep_state="deep_sleep"),
        "precoalesce-50us": Policy(kind="precoalesce", t_pdt=1e-5,
                                   t_dst=2e-4, hold_delay=5e-5,
                                   hold_frames=16, sleep_state="fast_wake",
                                   deep_state="deep_sleep"),
        "predict-ewma": Policy(kind="predict", t_pdt=1e-5, t_dst=2e-4,
                               forecast_weight=0.5, forecast_margin=2.0,
                               sleep_state="fast_wake",
                               deep_state="deep_sleep"),
    }


def resolve(scenarios: Optional[Iterable[Union[str, Scenario]]] = None,
            n_nodes: Optional[int] = None, seed: Optional[int] = None
            ) -> Dict[str, Scenario]:
    """Names/specs -> {name: Scenario}; default the whole catalog.
    ``n_nodes``/``seed`` rescale every entry (tiny topologies, CI smoke)."""
    if scenarios is None:
        scenarios = list_scenarios()
    specs = {}
    for s in scenarios:
        spec = get_scenario(s) if isinstance(s, str) else s
        if n_nodes is not None or seed is not None:
            spec = spec.scaled(n_nodes or spec.n_nodes, seed)
        specs[spec.name] = spec
    return specs


def evaluate_grid(traces: Dict, topo, policies: Dict,
                  pm: Optional[PowerModel] = None,
                  max_group: Optional[int] = None,
                  packing: str = "pow2"):
    """Sweep (traces x policies) with a hidden always-on baseline lane.

    The shared front half of :func:`run_suite` and the policy auto-tuner
    (``repro.tuning``): the baseline policy rides the batched grid as its
    own static group, stacked over every trace like any other lane, and
    comes back separated so callers can report each trace against ITS OWN
    baseline (the paper's protocol) — or keep the raw ``SimResult`` cells.

    Returns ``(base, results)`` — ``{trace: SimResult}`` for the baseline
    and ``{trace: {policy: SimResult}}`` for the grid.
    """
    pm = pm or PowerModel()
    base_key = unused_key(policies)
    grid = sweep_scenarios(traces, topo,
                           {base_key: _BASELINE_POLICY, **policies},
                           pm, max_group=max_group, packing=packing)
    base = {sc: res.pop(base_key) for sc, res in grid.items()}
    return base, grid


def run_suite(topo, scenarios=None, policies: Optional[Dict] = None,
              pm: Optional[PowerModel] = None, n_nodes: Optional[int] = None,
              max_group: Optional[int] = None, baseline: str = "baseline",
              packing: str = "pow2") -> Dict[str, Dict[str, dict]]:
    """Sweep (scenarios x policies) and report per-scenario tables.

    Returns ``{scenario: {policy: row}}`` where each row is the
    ``SimResult`` dict plus ``exec_overhead_pct`` / ``latency_overhead_pct``
    / ``energy_saved_pct`` / ``link_energy_saved_pct`` relative to that
    scenario's always-on baseline (included under ``baseline``).
    """
    pm = pm or PowerModel()
    policies = dict(policies) if policies is not None \
        else default_policy_grid()
    specs = resolve(scenarios, n_nodes)
    traces = {name: build_trace(spec, topo) for name, spec in specs.items()}
    base, grid = evaluate_grid(traces, topo, policies, pm,
                               max_group=max_group, packing=packing)
    return {sc: relative_rows(base[sc], res, baseline)
            for sc, res in grid.items()}


CSV_FIELDS = ("makespan", "exec_overhead_pct", "mean_latency",
              "latency_overhead_pct", "link_energy", "total_energy",
              "energy_saved_pct", "link_energy_saved_pct", "asleep_frac",
              "deep_frac")


def table_rows(results: Dict[str, Dict[str, dict]]):
    """Flatten suite results to CSV-ready dict rows."""
    for sc, rows in results.items():
        for pol, r in rows.items():
            yield {"scenario": sc, "policy": pol,
                   **{k: r[k] for k in CSV_FIELDS}}


def format_table(results: Dict[str, Dict[str, dict]]) -> str:
    """Human-readable per-scenario energy/degradation tables."""
    lines = []
    for sc, rows in results.items():
        lines.append(f"== {sc}")
        lines.append(f"  {'policy':<16} {'makespan':>11} {'overhead%':>10} "
                     f"{'energy_J':>12} {'saved%':>8} {'link_saved%':>12} "
                     f"{'asleep%':>8} {'deep%':>7}")
        for pol, r in rows.items():
            lines.append(
                f"  {pol:<16} {r['makespan']:>11.5g} "
                f"{r['exec_overhead_pct']:>10.2f} "
                f"{r['total_energy']:>12.5g} "
                f"{r['energy_saved_pct']:>8.2f} "
                f"{r['link_energy_saved_pct']:>12.2f} "
                f"{100 * r['asleep_frac']:>8.2f} "
                f"{100 * r['deep_frac']:>7.2f}")
    return "\n".join(lines)
