"""Scenario wrappers for the paper's §4 application generators.

``repro.traffic.generators`` stays the low-level API; these builders lift
the four applications into the scenario catalog so suites sweep them next
to the synthetic ML/HPC/datacenter families with one mechanism (and one
trace/plan cache).
"""
from __future__ import annotations

from repro.scenarios.spec import builder
from repro.traffic import generators as G


@builder("paper_app")
def paper_app(topo, n_nodes, seed, app, **kw):
    """Any of the paper's generators (``lammps``/``patmos``/``mlwf``/
    ``alexnet``) as a scenario; extra params pass through (e.g. ``iters``).
    The generators are deterministic, so ``seed`` is accepted for the
    uniform builder signature but unused."""
    if app not in G.GENERATORS:
        raise KeyError(f"unknown app {app!r}; have {sorted(G.GENERATORS)}")
    return G.GENERATORS[app](topo, n_nodes=n_nodes, **kw)
