"""HPC scenario synthesis: stencil/halo and bulk-synchronous iteration
structures (the paper's §4 application class, parameterized).

Two families:

* ``stencil_halo`` — iterative nearest-neighbor halo exchange on a
  pseudo-``dims``-D process grid with a periodic global residual
  all-reduce: the LAMMPS/PATMOS-style "compute, exchange ghosts, reduce"
  skeleton with tunable compute/communication ratio and imbalance.
* ``bsp_spectral`` — alternating compute + global transpose (all-to-all)
  rounds, the FFT/spectral-solver signature whose dense all-to-all bursts
  are the hardest case for link sleeping.

Seeded per-node compute imbalance (a few percent by default) staggers
injection times the way real iterative codes do — perfectly synchronized
ranks would give the EEE policies an unrealistically easy square wave.
"""
from __future__ import annotations

import numpy as np

from repro.scenarios.spec import builder, rng
from repro.traffic import collectives as C
from repro.traffic.generators import allocate
from repro.traffic.trace import Trace


@builder("stencil_halo")
def stencil_halo(topo, n_nodes, seed, iters=12, dims=3, halo_bytes=128 << 10,
                 compute_secs=2e-3, imbalance=0.05, reduce_every=4,
                 reduce_bytes=8 << 10, mapping="linear"):
    """BSP stencil: {compute, halo exchange, periodic residual allreduce}."""
    nodes = allocate(topo, n_nodes, mapping, seed)
    t = Trace(nodes=nodes, name=f"stencil{dims}d")
    r = rng(seed)
    t.rounds(C.broadcast(nodes, 1 << 20))        # domain decomposition
    t.compute(r.uniform(0.8, 1.2, n_nodes) * 10 * compute_secs)   # setup
    for i in range(iters):
        t.compute(r.uniform(1 - imbalance, 1 + imbalance, n_nodes)
                  * compute_secs)
        t.rounds(C.p2p_halo(nodes, halo_bytes, dims=dims))
        if (i + 1) % reduce_every == 0:
            t.rounds(C.allreduce(nodes, reduce_bytes))   # residual norm
    t.rounds(C.reduce(nodes, 1 << 20), barrier_last=True)  # gather result
    return t


@builder("bsp_spectral")
def bsp_spectral(topo, n_nodes, seed, iters=8, transpose_bytes=512 << 10,
                 compute_secs=1.5e-3, imbalance=0.03, reduce_every=2,
                 mapping="linear"):
    """Spectral/FFT skeleton: compute, forward transpose (all-to-all),
    compute, inverse transpose, periodic convergence allreduce."""
    nodes = allocate(topo, n_nodes, mapping, seed)
    t = Trace(nodes=nodes, name="spectral")
    r = rng(seed)
    t.rounds(C.broadcast(nodes, 4 << 20))        # operator setup
    t.compute(r.uniform(0.9, 1.1, n_nodes) * 5 * compute_secs)
    for i in range(iters):
        t.compute(r.uniform(1 - imbalance, 1 + imbalance, n_nodes)
                  * compute_secs)
        t.rounds(C.alltoall(nodes, transpose_bytes))
        t.compute(r.uniform(1 - imbalance, 1 + imbalance, n_nodes)
                  * compute_secs)
        t.rounds(C.alltoall(nodes, transpose_bytes))
        if (i + 1) % reduce_every == 0:
            t.rounds(C.allreduce(nodes, 4 << 10))
    t.rounds(C.reduce(nodes, 1 << 20), barrier_last=True)
    return t
