"""Declarative scenario specs: named, parametric, reproducible workloads.

A :class:`Scenario` is a frozen value describing HOW to synthesize a
:class:`~repro.traffic.trace.Trace` — a builder name, a node count, a seed
and a parameter tuple — without holding the trace itself.  Specs hash, so
they key caches and registries, travel through configs, and scale
(``scaled``) without touching builder code.

Builders are plain functions ``fn(topo, n_nodes, seed, **params) -> Trace``
registered under a string key with :func:`builder`; keeping the spec ->
builder indirection declarative means a catalog entry is data, not code.

``build_trace`` memoizes the synthesized Trace per (spec, topology) in a
bounded LRU.  That identity-stability is load-bearing: the trace-plan cache
(``repro.traffic.plan``) keys on trace identity, so every suite run, sweep
group and warm benchmark pass of a scenario hits ONE compiled plan — the
"plan cache keyed per scenario" contract.  Any RNG a builder uses must be
derived from ``seed`` (``rng(seed)`` below — counter-based Philox, stable
across platforms); the replay hot path itself never sees host RNG because
synthesis happens once, before planning.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict

import numpy as np

_BUILDERS: Dict[str, Callable] = {}


def builder(name: str):
    """Register a trace builder ``fn(topo, n_nodes, seed, **params)``."""
    def deco(fn):
        assert name not in _BUILDERS, f"duplicate builder {name!r}"
        _BUILDERS[name] = fn
        return fn
    return deco


def builder_names() -> list:
    return sorted(_BUILDERS)


def rng(seed: int) -> np.random.Generator:
    """The scenario RNG: counter-based Philox, so a (seed, draw-sequence)
    pair reproduces bit-identically across platforms and numpy versions."""
    return np.random.Generator(np.random.Philox(seed))


def params_of(**kw) -> tuple:
    """Normalize builder kwargs into the spec's hashable params tuple."""
    return tuple(sorted(kw.items()))


@dataclass(frozen=True)
class Scenario:
    """One named, parametric workload (a catalog entry).

    ``family`` groups catalog listings: ``ml`` (training phases from
    ``repro.configs``), ``hpc`` (stencil / BSP iteration structures),
    ``dc`` (stochastic datacenter arrivals), ``app`` (the paper's §4
    application generators).
    """
    name: str
    family: str                  # ml | hpc | dc | app
    builder: str
    n_nodes: int
    seed: int = 0
    params: tuple = ()           # sorted (key, value) pairs, see params_of
    description: str = ""

    def scaled(self, n_nodes: int, seed: int | None = None) -> "Scenario":
        """The same scenario on a different allocation size (and optionally
        a different seed) — builders auto-derive internal shape (e.g. the
        DP/TP/PP grid) from ``n_nodes``."""
        return dataclasses.replace(
            self, n_nodes=n_nodes,
            seed=self.seed if seed is None else seed)

    def build(self, topo):
        return build_trace(self, topo)


# -- per-(spec, topology) trace memo ----------------------------------------
# Identity-stable traces keep the downstream plan cache hot; bounded so a
# long-running catalog sweep cannot grow host memory without limit.
_TRACE_CACHE: OrderedDict = OrderedDict()
_TRACE_CACHE_MAX = 64


def build_trace(spec: Scenario, topo):
    """Synthesize (or fetch the cached) Trace for a scenario on a topology."""
    if spec.builder not in _BUILDERS:
        raise KeyError(f"unknown builder {spec.builder!r}; "
                       f"have {builder_names()}")
    key = (spec, topo)
    hit = _TRACE_CACHE.get(key)
    if hit is not None:
        _TRACE_CACHE.move_to_end(key)
        return hit
    tr = _BUILDERS[spec.builder](topo, n_nodes=spec.n_nodes, seed=spec.seed,
                                 **dict(spec.params))
    tr.name = spec.name
    _TRACE_CACHE[key] = tr
    while len(_TRACE_CACHE) > _TRACE_CACHE_MAX:
        _TRACE_CACHE.popitem(last=False)
    return tr


def trace_cache_clear() -> None:
    _TRACE_CACHE.clear()
