"""ML-training scenario synthesis from the repo's model configs.

Lowers a :class:`~repro.configs.base.ModelConfig` plus a 3D parallelism
grid (DP x PP x TP) into the phase-structured trace language: per-stage
forward/backward compute, fused tensor-parallel activation all-reduces,
pipeline point-to-point activation/gradient transfers, and bucketed
data-parallel gradient all-reduces — the collective schedule a training
step of that architecture actually puts on the network.

Approximations (traffic structure, not training math):

* per-layer TP all-reduces fuse into two per stage pass (attention-side and
  MLP-side aggregates) with the stage's total volume preserved — keeps
  trace length bounded by the grid, not by ``num_layers``;
* compute phases derive from the analytic per-stage parameter count
  (``ModelConfig.layer_param_count``) at a nominal accelerator throughput
  — their role is realistic gap structure between network phases (what the
  power policies react to), not runtime prediction;
* the DP gradient all-reduce runs after the backward pipeline drains
  (no overlap), split into ``grad_buckets`` buckets per stage.

Node layout on the allocation: ``index(d, s, t) = (d*pp + s)*tp + t`` —
TP groups are contiguous (they carry the densest traffic), pipeline
neighbors sit ``tp`` apart, DP replicas ``pp*tp`` apart.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import get_config
from repro.scenarios.spec import builder, rng
from repro.traffic import collectives as C
from repro.traffic.generators import allocate
from repro.traffic.trace import Trace


def derive_grid(n_nodes: int, dp: int = 0, tp: int = 0, pp: int = 0):
    """Fill in unset (0) grid dims for ``n_nodes`` participants.

    Defaults: TP 2 from 8 nodes up, PP 2 from 16 nodes up, DP takes the
    rest.  All dims must be powers of two (collective algorithms) and
    multiply to ``n_nodes``.
    """
    assert n_nodes >= 1 and (n_nodes & (n_nodes - 1)) == 0, \
        f"ml_training needs a power-of-two allocation, got {n_nodes}"
    tp = tp or (2 if n_nodes >= 8 else 1)
    pp = pp or (2 if n_nodes >= 16 else 1)
    dp = dp or n_nodes // (tp * pp)
    assert dp * tp * pp == n_nodes, \
        f"dp*tp*pp = {dp}*{tp}*{pp} != n_nodes = {n_nodes}"
    for d in (dp, tp, pp):
        assert d >= 1 and (d & (d - 1)) == 0, f"non-power-of-two dim {d}"
    return dp, tp, pp


def _merged(rounds_per_group):
    """Merge per-group collective rounds into shared message steps: round r
    of every group lands in ONE step (the groups run concurrently)."""
    return [np.concatenate(rs) for rs in zip(*rounds_per_group)]


@builder("ml_training")
def ml_training(topo, n_nodes, seed, arch, iters=2, dp=0, tp=0, pp=0,
                tokens_per_iter=8192, micro_batches=2, grad_bytes=2,
                act_bytes=2, hw_flops=100e12, opt_bw=200e9, grad_buckets=4,
                mapping="linear"):
    """One trace = ``iters`` training steps of ``arch`` on a DP x PP x TP
    grid (unset dims derived from ``n_nodes``, see ``derive_grid``)."""
    cfg = get_config(arch)
    dp, tp, pp = derive_grid(n_nodes, dp, tp, pp)
    nodes = allocate(topo, n_nodes, mapping, seed)
    t = Trace(nodes=nodes, name=f"ml-{arch}")
    r = rng(seed)

    def idx(d, s, tq):
        return (d * pp + s) * tp + tq

    stages = [np.asarray([idx(d, s, tq) for d in range(dp)
                          for tq in range(tp)]) for s in range(pp)]
    L = cfg.num_layers
    lps = -(-L // pp)                            # layers per stage (ceil)
    stage_layers = [min(L - s * lps, lps) for s in range(pp)]
    layer_b = cfg.layer_param_count() * grad_bytes
    stage_param_b = [n * layer_b for n in stage_layers]
    stage_param_b[0] += cfg.embed_param_count() * grad_bytes

    tokens_micro = max(tokens_per_iter // (dp * micro_batches), 1)
    act_volume = tokens_micro * cfg.d_model * act_bytes   # one stream copy
    fwd_secs = [2 * (stage_param_b[s] // grad_bytes) * tokens_micro
                / (tp * hw_flops) for s in range(pp)]

    def stage_compute(s, secs):
        arr = np.zeros(n_nodes, np.float64)
        arr[stages[s]] = secs
        t.compute(arr)

    def tp_allreduce(s, nbytes):
        if tp < 2 or nbytes <= 0:
            return
        groups = [nodes[[idx(d, s, tq) for tq in range(tp)]]
                  for d in range(dp)]
        t.rounds(_merged([C.allreduce(g, max(int(nbytes), 64))
                          for g in groups]))

    def p2p(s_from, s_to, nbytes):
        msgs = [[int(nodes[idx(d, s_from, tq)]), int(nodes[idx(d, s_to, tq)]),
                 max(int(nbytes), 64)]
                for d in range(dp) for tq in range(tp)]
        t.messages(msgs)

    # -- setup: weight shards to every rank, jittered init work ------------
    t.rounds(C.broadcast(nodes, max(stage_param_b[0] // tp, 64)))
    t.compute(r.uniform(5e-3, 15e-3, n_nodes))

    for _ in range(iters):
        for _m in range(micro_batches):
            for s in range(pp):                  # forward pipeline
                stage_compute(s, fwd_secs[s])
                tp_allreduce(s, stage_layers[s] * act_volume)   # attn side
                tp_allreduce(s, stage_layers[s] * act_volume)   # mlp side
                if s < pp - 1:
                    p2p(s, s + 1, act_volume // tp)
            for s in reversed(range(pp)):        # backward pipeline
                stage_compute(s, 2 * fwd_secs[s])
                tp_allreduce(s, 2 * stage_layers[s] * act_volume)
                if s > 0:
                    p2p(s, s - 1, act_volume // tp)
        if dp > 1:                               # bucketed gradient sync
            groups, sizes = [], []
            for s in range(pp):
                for tq in range(tp):
                    groups.append(nodes[[idx(d, s, tq) for d in range(dp)]])
                    sizes.append(max(stage_param_b[s]
                                     // (tp * grad_buckets), 64))
            merged = _merged([C.allreduce(g, b)
                              for g, b in zip(groups, sizes)])
            for _k in range(grad_buckets):
                t.rounds(merged)
        for s in range(pp):                      # optimizer update
            stage_compute(s, stage_param_b[s] / (tp * opt_bw))
    t.rounds(C.allreduce(nodes, 64), barrier_last=True)   # loss scalar
    return t


@builder("moe_training")
def moe_training(topo, n_nodes, seed, arch, iters=2, layer_groups=4,
                 tokens_per_iter=8192, act_bytes=2, grad_bytes=2,
                 hw_flops=100e12, opt_bw=200e9, capacity_factor=1.25,
                 mapping="linear"):
    """Expert-parallel MoE training steps: token-routing all-to-alls.

    One trace = ``iters`` training steps of a MoE ``arch`` (e.g.
    ``qwen3-moe-30b-a3b``) sharded expert-parallel over the whole
    allocation.  Each of ``layer_groups`` fused layer blocks runs
    attention/router compute, a **dispatch all-to-all** (top-k routed token
    activations), expert FFN compute, and a **combine all-to-all** — then
    the backward mirror (gradients retrace the routes at 2x compute) and an
    expert-gradient all-reduce per step.  The all-to-all phases produce the
    dense symmetric bursts separated by compute gaps that distinguish MoE
    traffic from the dense-model pipeline of ``ml_training``.
    """
    cfg = get_config(arch)
    assert cfg.num_experts > 0, f"{arch} is not a MoE config"
    nodes = allocate(topo, n_nodes, mapping, seed)
    t = Trace(nodes=nodes, name=f"moe-{arch}")
    r = rng(seed)

    L = cfg.num_layers
    groups = min(layer_groups, L)
    layers_per = -(-L // groups)
    # routed token volume per device per layer: every token ships to its
    # top-k experts (capacity-padded), spread over the EP group
    topk = max(cfg.experts_per_token, 1)
    tok_dev = max(tokens_per_iter // n_nodes, 1)
    route_bytes = int(tok_dev * topk * capacity_factor * cfg.d_model
                      * act_bytes)
    # per-device expert shard: every layer's full expert grid split over
    # the allocation (the gradient sync/optimizer phases scale with the
    # whole stack, like ml_training's per-stage stage_param_b)
    expert_param_b = cfg.layer_param_count() * L * grad_bytes
    shard_param_b = max(expert_param_b // n_nodes, 64)
    attn_secs = 2 * (cfg.d_model * cfg.d_model * 4) * tok_dev / hw_flops
    ffn_secs = (2 * 3 * cfg.d_model * cfg.d_ff * topk
                * capacity_factor * tok_dev) / hw_flops

    def a2a(nbytes):
        t.rounds(C.alltoall(nodes, max(int(nbytes), 64)))

    # weight-shard broadcast + jittered init
    t.rounds(C.broadcast(nodes, shard_param_b))
    t.compute(r.uniform(5e-3, 15e-3, n_nodes))

    for _ in range(iters):
        for _g in range(groups):                 # forward blocks
            t.compute(r.uniform(0.9, 1.1, n_nodes) * attn_secs * layers_per)
            a2a(route_bytes * layers_per)        # dispatch
            t.compute(r.uniform(0.9, 1.1, n_nodes) * ffn_secs * layers_per)
            a2a(route_bytes * layers_per)        # combine
        for _g in range(groups):                 # backward blocks (2x)
            t.compute(2 * r.uniform(0.9, 1.1, n_nodes) * ffn_secs
                      * layers_per)
            a2a(2 * route_bytes * layers_per)    # grad dispatch + combine
        # expert/attention gradient sync + optimizer
        t.rounds(C.allreduce(nodes, shard_param_b))
        t.compute(np.full(n_nodes, shard_param_b / opt_bw))
    t.rounds(C.allreduce(nodes, 64), barrier_last=True)   # loss scalar
    return t
