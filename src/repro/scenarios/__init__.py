"""Scenario engine: parametric workload synthesis + the named catalog.

Public surface:

* :class:`~repro.scenarios.spec.Scenario`, ``build_trace`` — declarative
  specs lowering to :class:`~repro.traffic.trace.Trace`;
* ``register_scenario`` / ``get_scenario`` / ``list_scenarios`` /
  ``catalog`` — the registry (built-ins installed on import);
* ``run_suite`` / ``default_policy_grid`` / ``format_table`` /
  ``table_rows`` — the (scenario x policy) suite runner on the
  multi-trace batched replay path.
"""
from repro.scenarios import catalog as _catalog  # noqa: F401 (registers)
from repro.scenarios.registry import (catalog, get_scenario,  # noqa: F401
                                      list_scenarios, register_scenario)
from repro.scenarios.spec import (Scenario, build_trace,  # noqa: F401
                                  builder, builder_names, params_of, rng,
                                  trace_cache_clear)
from repro.scenarios.suite import (default_policy_grid,  # noqa: F401
                                   evaluate_grid, format_table, run_suite,
                                   table_rows)

__all__ = [
    "Scenario", "build_trace", "builder", "builder_names", "params_of",
    "rng", "trace_cache_clear", "catalog", "get_scenario", "list_scenarios",
    "register_scenario", "default_policy_grid", "evaluate_grid",
    "format_table", "run_suite", "table_rows",
]
