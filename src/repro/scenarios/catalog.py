"""The built-in scenario catalog.

Twelve named scenarios spanning four families (see README for the table):

* ``ml-*``  — training phases synthesized from ``repro.configs`` model
  definitions through the DP/PP/TP collective schedule (``scenarios.ml``);
* ``hpc-*`` — stencil/halo and spectral BSP iteration structures;
* ``dc-*``  — stochastic datacenter arrivals (Poisson / ON-OFF / incast /
  hotspot) — the whole family shares one plan shape by construction, so it
  replays as a single stacked (scenario x policy) grid program;
* ``app-*`` — the paper's §4 application generators as catalog entries.

Default allocations are 16 nodes (runs on every topology from the 80-node
small Megafly up); ``Scenario.scaled(n)`` rescales any entry — builders
re-derive internal structure (e.g. the parallelism grid) from ``n``.

The catalog is the unit the policy auto-tuner (``repro.tuning``) consumes:
``tune_catalog`` searches the policy space per entry and hands back each
workload's energy/degradation frontier and budget winner, so every entry
here doubles as a named workload class an operator can ask
``launch.power_advisor`` about by name.
"""
from __future__ import annotations

from repro.scenarios import apps, hpc, ml, stochastic  # noqa: F401 (builders)
from repro.scenarios.registry import register_scenario
from repro.scenarios.spec import Scenario, params_of

# Display/report ordering of the scenario families (suite tables, tuner
# reports, the experiments scripts' --families flag).
FAMILIES = ("ml", "hpc", "dc", "app")

CATALOG = [
    # -- ML training (from configs/*) -------------------------------------
    Scenario(
        "ml-qwen2-1.5b", "ml", "ml_training", 16, seed=11,
        params=params_of(arch="qwen2-1.5b", iters=2),
        description="qwen2-1.5b training steps on a DP4xPP2xTP2 grid: "
                    "fused TP all-reduces, pipeline P2P, bucketed DP "
                    "gradient sync"),
    Scenario(
        "ml-gemma3-4b", "ml", "ml_training", 16, seed=12,
        params=params_of(arch="gemma3-4b", iters=2, tokens_per_iter=16384,
                         grad_buckets=6),
        description="gemma3-4b training steps, larger grads/activations "
                    "and finer gradient bucketing than ml-qwen2-1.5b"),
    Scenario(
        "ml-qwen3-moe", "ml", "moe_training", 16, seed=13,
        params=params_of(arch="qwen3-moe-30b-a3b", iters=2),
        description="qwen3-moe-30b-a3b expert-parallel training steps: "
                    "top-8 token-routing dispatch/combine all-to-alls per "
                    "fused layer block — dense symmetric bursts between "
                    "compute gaps (the dual-mode sleep-ladder stressor)"),
    # -- HPC iteration structures -----------------------------------------
    Scenario(
        "hpc-stencil3d", "hpc", "stencil_halo", 16, seed=21,
        params=params_of(dims=3, iters=12),
        description="3-D halo exchange + periodic residual all-reduce "
                    "(LAMMPS-style BSP skeleton)"),
    Scenario(
        "hpc-stencil2d", "hpc", "stencil_halo", 16, seed=22,
        params=params_of(dims=2, iters=12, halo_bytes=512 << 10,
                         compute_secs=4e-3),
        description="2-D stencil: fewer, fatter halos and a higher "
                    "compute/communication ratio"),
    Scenario(
        "hpc-spectral", "hpc", "bsp_spectral", 16, seed=23,
        params=params_of(iters=8),
        description="spectral solver: paired all-to-all transposes per "
                    "iteration — dense bursts, worst case for sleeping"),
    # -- stochastic datacenter arrivals -----------------------------------
    Scenario(
        "dc-poisson", "dc", "poisson", 16, seed=31,
        params=params_of(rate=2000.0),
        description="memoryless Poisson flows between uniform pairs, "
                    "heavy-tailed sizes"),
    Scenario(
        "dc-hotspot", "dc", "poisson", 16, seed=32,
        params=params_of(rate=2500.0, hot_frac=0.6),
        description="Poisson arrivals with 60% of flows aimed at a hot "
                    "destination set"),
    Scenario(
        "dc-onoff", "dc", "onoff", 16, seed=33,
        params=params_of(),
        description="Markov-modulated ON-OFF bursts: near-saturation "
                    "windows between near-idle ones (wake-storm regime)"),
    Scenario(
        "dc-incast", "dc", "incast", 16, seed=34,
        params=params_of(fan_in=8),
        description="partition-aggregate incast: synchronized fan-in to a "
                    "rotating aggregator over background trickle"),
    # -- paper §4 applications --------------------------------------------
    Scenario(
        "app-lammps", "app", "paper_app", 16, seed=41,
        params=params_of(app="lammps", iters=10),
        description="the paper's LAMMPS generator (halo + all-reduce "
                    "iterations, periodic FFT all-to-all)"),
    Scenario(
        "app-alexnet", "app", "paper_app", 16, seed=42,
        params=params_of(app="alexnet", iters=3),
        description="the paper's AlexNet generator (per-layer backprop "
                    "all-reduce bursts)"),
]

for _s in CATALOG:
    register_scenario(_s)
