"""Stochastic datacenter arrival scenarios: Poisson, ON-OFF bursty, incast.

The related EEE literature (Cenedese et al. arXiv:1503.02843,
Herrería-Alonso et al. arXiv:1510.03694) shows power/performance
trade-offs INVERTING with inter-arrival structure — smooth Poisson
traffic rewards aggressive sleeping while bursty ON-OFF traffic punishes
it with wake storms.  These builders span that axis.

Time is discretized into ``windows`` service windows.  Each window is one
trace step pair: a per-node compute advance of ~``window_secs`` with
seeded jitter (staggering injection clocks so arrivals spread inside the
window instead of landing in lockstep), then the window's sampled flows as
one message step.  All sampling runs ONCE at synthesis time on the seeded
counter-based scenario RNG (``spec.rng``) — the replay hot path is the
ordinary compiled plan executor, no RNG on device and none on host.

Every builder keeps per-window flow counts within one message bucket
(``max_flows`` ≤ 64) and emits exactly ``windows`` message steps, so the
whole ``dc-*`` catalog family lowers to the SAME plan shape and stacks
along the multi-trace axis (``plan.stack_plans``) into a single compiled
(scenario x policy) grid program.
"""
from __future__ import annotations

import numpy as np

from repro.scenarios.spec import builder, rng
from repro.traffic.generators import allocate
from repro.traffic.trace import Trace


def _flow_sizes(r, n, mean_bytes):
    """Heavy-tailed flow sizes: lognormal around ``mean_bytes``, clipped to
    [64 B, 4 MiB] — mice dominate counts, elephants dominate bytes."""
    raw = r.lognormal(mean=np.log(mean_bytes), sigma=1.2, size=n)
    return np.clip(raw, 64, 4 << 20).astype(np.int64)


def _check(n_nodes, windows):
    """Degenerate-parameter guard shared by every builder: src != dst
    pairing needs two endpoints, and zero windows would synthesize an
    empty trace whose Step arrays break the dc-* plan-shape guarantee."""
    if n_nodes < 2:
        raise ValueError(f"stochastic scenarios need n_nodes >= 2 "
                         f"(got {n_nodes})")
    if windows < 1:
        raise ValueError(f"stochastic scenarios need windows >= 1 "
                         f"(got {windows})")


def _pairs(r, nodes, m, dst_weights=None):
    """m (src, dst) pairs with src != dst; optional non-uniform dst bias."""
    n = len(nodes)
    src_i = r.integers(0, n, m)
    if dst_weights is None:
        dst_i = (src_i + r.integers(1, n, m)) % n
    else:
        dst_i = r.choice(n, size=m, p=dst_weights)
        clash = dst_i == src_i
        dst_i[clash] = (dst_i[clash] + 1) % n
    return nodes[src_i], nodes[dst_i]


def _window_compute(t, r, n, window_secs, jitter):
    t.compute(r.uniform(1 - jitter, 1 + jitter, n) * window_secs)


def _emit_window(t, r, nodes, m, mean_bytes, max_flows, dst_weights=None,
                 barrier=False):
    m = int(np.clip(m, 1, max_flows))
    src, dst = _pairs(r, nodes, m, dst_weights)
    t.messages(np.stack([src, dst, _flow_sizes(r, m, mean_bytes)], axis=1),
               barrier=barrier)


@builder("poisson")
def poisson(topo, n_nodes, seed, windows=24, window_secs=5e-3, rate=2000.0,
            mean_bytes=32 << 10, jitter=0.5, hot_frac=0.0, max_flows=64,
            mapping="linear"):
    """Memoryless arrivals: per window, Poisson(rate x window) flows between
    uniform (or, with ``hot_frac``, skewed) endpoint pairs."""
    _check(n_nodes, windows)
    nodes = allocate(topo, n_nodes, mapping, seed)
    t = Trace(nodes=nodes, name="poisson")
    r = rng(seed)
    w = None
    if hot_frac > 0:                  # a few hot destinations take hot_frac
        # clamp below n_nodes: every node hot would zero-divide the cold
        # weights (and make the "hot subset" meaningless)
        n_hot = max(min(n_nodes // 8, n_nodes - 1), 1)
        w = np.full(n_nodes, (1 - hot_frac) / (n_nodes - n_hot))
        w[r.choice(n_nodes, n_hot, replace=False)] = hot_frac / n_hot
    for i in range(windows):
        _window_compute(t, r, n_nodes, window_secs, jitter)
        _emit_window(t, r, nodes, r.poisson(rate * window_secs), mean_bytes,
                     max_flows, w, barrier=i == windows - 1)
    return t


@builder("onoff")
def onoff(topo, n_nodes, seed, windows=24, window_secs=5e-3, rate_on=6000.0,
          rate_off=100.0, p_on=0.35, p_stay_on=0.6, mean_bytes=64 << 10,
          jitter=0.5, max_flows=64, mapping="linear"):
    """Bursty two-state (Markov-modulated) arrivals: windows flip between
    an ON state near saturation and a near-idle OFF state — the wake-storm
    regime where frame-coalescing/EEE trade-offs invert."""
    _check(n_nodes, windows)
    nodes = allocate(topo, n_nodes, mapping, seed)
    t = Trace(nodes=nodes, name="onoff")
    r = rng(seed)
    on = r.random() < p_on
    for i in range(windows):
        _window_compute(t, r, n_nodes, window_secs, jitter)
        rate = rate_on if on else rate_off
        _emit_window(t, r, nodes, r.poisson(rate * window_secs), mean_bytes,
                     max_flows, barrier=i == windows - 1)
        on = r.random() < (p_stay_on if on else p_on)
    return t


@builder("incast")
def incast(topo, n_nodes, seed, windows=24, window_secs=5e-3, fan_in=8,
           flow_bytes=256 << 10, background_rate=200.0,
           mean_bytes=16 << 10, jitter=0.5, max_flows=64, mapping="linear"):
    """Partition-aggregate incast: each window, one random aggregator pulls
    ``fan_in`` synchronized responses (serializing at its access link) over
    a trickle of background flows."""
    _check(n_nodes, windows)
    nodes = allocate(topo, n_nodes, mapping, seed)
    t = Trace(nodes=nodes, name="incast")
    r = rng(seed)
    fan_in = min(fan_in, max_flows)   # keep the one-bucket shape guarantee
    # at least one response per window: fan_in <= 0 with a quiet background
    # (m_bg == 0) would otherwise emit an EMPTY message step, changing the
    # step/shape structure the dc-* stacking guarantee depends on
    fan_in = max(min(fan_in, n_nodes - 1), 1)
    for i in range(windows):
        _window_compute(t, r, n_nodes, window_secs, jitter)
        agg = int(r.integers(0, n_nodes))
        srcs = (agg + 1 + r.choice(n_nodes - 1, fan_in,
                                   replace=False)) % n_nodes
        msgs = [[int(nodes[s]), int(nodes[agg]), int(flow_bytes)]
                for s in srcs]
        m_bg = max(0, min(int(r.poisson(background_rate * window_secs)),
                          max_flows - len(msgs)))
        if m_bg:
            src, dst = _pairs(r, nodes, m_bg)
            msgs += np.stack([src, dst, _flow_sizes(r, m_bg, mean_bytes)],
                             axis=1).tolist()
        t.messages(msgs, barrier=i == windows - 1)
    return t
