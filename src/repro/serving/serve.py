"""Serving: prefill + batched greedy decode with sharded KV caches."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        out = M.forward(params, batch, cfg, mode="prefill")
        last = out["logits"][:, -1]
        return jnp.argmax(last, axis=-1).astype(jnp.int32), out["cache"]
    return prefill_step


def make_serve_step(cfg):
    """One decode step: (params, cache, tokens (B,1)) -> (next (B,1), cache)."""
    def serve_step(params, cache, tokens):
        logits, cache = M.decode_step(params, cache, tokens, cfg)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache
    return serve_step


def generate(params, cfg, prompt, steps, cache_len=None):
    """Eager helper for examples/tests: prefill a prompt then greedy-decode.

    prompt: (B, S) int32.  Returns (B, steps) generated tokens.

    ``cache_len`` pre-sizes the linear KV caches (sequence axis) instead
    of the default tight fit of ``S + steps`` — serving stacks allocate
    one bucketed cache length and reuse it across requests, so the
    decode-step program is compiled once per bucket rather than once per
    (prompt, steps) pair.  Must fit the whole generation; the extra slots
    are bit-inert (attention masks positions past the write cursor).
    """
    B, S = prompt.shape
    max_len = S + steps
    if cache_len is None:
        cache_len = max_len
    if cache_len < max_len:
        raise ValueError(
            f"cache_len={cache_len} cannot hold prompt ({S}) + "
            f"generated ({steps}) tokens; need >= {max_len}")
    batch = {"tokens": prompt}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros((B, cfg.num_patches, cfg.d_model),
                                          cfg.dtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((B, S, cfg.d_model), cfg.dtype)
    out = M.forward(params, batch, cfg, mode="prefill")
    cache = out["cache"]
    # grow linear caches to the requested bucket (>= prefill S + steps)
    def grow(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v", "k_global", "v_global"):
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, cache_len - x.shape[2])
            return jnp.pad(x, pad)
        return x
    cache = jax.tree_util.tree_map_with_path(grow, cache)
    tok = jnp.argmax(out["logits"][:, -1], axis=-1).astype(
        prompt.dtype)[:, None]
    outs = [tok]
    step = jax.jit(make_serve_step(cfg))
    for _ in range(steps - 1):
        tok, cache = step(params, cache, tok)
        tok = tok.astype(prompt.dtype)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)
