"""Core power-saving library (the paper's contribution).

Times are float64 seconds: microsecond-scale transitions over 1000+ second
simulations exceed f32 resolution, so the simulator enables x64.  Model code
(`repro.models`) uses explicit f32/bf16 dtypes throughout and is unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.eee import (  # noqa: E402,F401
    EEE_STATES, FAST_WAKE, DEEP_SLEEP, LinkState, Policy, PowerModel,
)
