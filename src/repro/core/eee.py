"""EEE link power states, power-management policies, and the system power
model (paper §2.4, §3.1, Tables 3/5/6)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class LinkState:
    """One EEE low-power state (transition targets; Wake is implicit)."""
    name: str
    t_w: float            # transition sleep -> wake (s)
    t_s: float            # transition wake -> sleep (s)
    power_frac: float     # link power in this state / wake power

    def __post_init__(self):
        # power_frac == 0 is a true off state (beyond 802.3bj, but the
        # FSM lowers it like any other row); >= 1 would never save energy
        assert self.t_w > 0 and self.t_s > 0 and 0 <= self.power_frac < 1


# Table 6 values (derived from EEE / 802.3bj, Table 3)
FAST_WAKE = LinkState("fast_wake", t_w=375e-9, t_s=200e-9, power_frac=0.4)
DEEP_SLEEP = LinkState("deep_sleep", t_w=4.48e-6, t_s=2e-6, power_frac=0.1)
EEE_STATES = {"fast_wake": FAST_WAKE, "deep_sleep": DEEP_SLEEP}


@dataclass(frozen=True)
class Policy:
    """Power-down policy for every port in the network.

    kind:
      * ``none``       — links always awake (baseline; t_PDT = inf).
      * ``fixed``      — constant ``t_pdt`` on every port (§2.5, PDT).
      * ``perfbound``  — per-port adaptive t_PDT from the inactivity
                         histogram, degradation bound ``bound`` (§2.5 [28]).
      * ``perfbound_correct`` — PerfBound + miss-ratio corrective factor
                         (§3.4, the paper's contribution).
      * ``dual``       — two-level sleep ladder (DESIGN.md §6): fixed
                         ``t_pdt`` drops the port into ``sleep_state``
                         (Fast Wake), a second timer ``t_dst`` demotes it
                         to ``deep_state`` (Deep Sleep).
      * ``coalesce``   — the dual ladder plus frame coalescing: the frame
                         that would wake a sleeping port is held up to
                         ``max_delay`` (early release once ~``max_frames``
                         frames queue), so the port sleeps through bursts.
      * ``perfbound_dual`` — the paper-enhancement ladder: PerfBound
                         drives t_PDT as usual AND selects the per-port
                         demotion threshold from the same histograms, so
                         deep sleep engages only where the predicted
                         residual idle amortizes its extra wake penalty.
      * ``precoalesce``  — hold-at-source coalescing (arXiv 2005.13267):
                         the dual ladder, but the deferral happens at the
                         INJECTION link only — frames queue at the source
                         for up to ``hold_delay`` (early release once
                         ~``hold_frames`` queue), so every downstream port
                         sees pre-formed bursts and sleeps undisturbed.
      * ``predict``      — proactive forecaster (arXiv 1503.02843): an
                         EWMA over the per-port inactivity histograms —
                         with a dominant-mode (periodogram) override for
                         periodic BSP traffic — predicts the NEXT gap and
                         schedules t_PDT and the demotion timer ahead of
                         it: a predicted-long gap sleeps/demotes at onset,
                         a predicted-short gap holds the port awake.
    hist_mode: ``keep_all`` | ``self_clear`` | ``circular`` (§3.2/§4).
    """
    kind: str = "none"
    sleep_state: str = "deep_sleep"
    t_pdt: float = 0.0
    bound: float = 0.01
    # -- dual-mode sleep ladder (dual / coalesce / perfbound_dual) ---------
    deep_state: str = "deep_sleep"    # second FSM row (lowers to numbers)
    t_dst: float = 1e-3               # demotion timer after sleep onset (s);
    #                                   perfbound_dual: initial threshold
    # -- frame coalescing (kind == "coalesce") -----------------------------
    max_delay: float = 0.0            # max wake deferral per sleep cycle (s)
    max_frames: int = 32              # queue bound: est. early-wake trigger
    # -- hold-at-source pre-coalescing (kind == "precoalesce") -------------
    hold_delay: float = 0.0           # max injection hold per sleep cycle (s)
    hold_frames: int = 32             # source queue bound: early release
    # -- arrival forecasting (kind == "predict") ---------------------------
    forecast_weight: float = 0.5      # EWMA weight of the newest gap (0=off)
    forecast_margin: float = 2.0      # safety factor on the break-even gaps
    period_conf: float = 0.6          # mode-bin share that flips to periodic
    hist_mode: str = "keep_all"
    hist_bins: int = 200
    hist_bin_width: float = 10e-6     # seconds/bin (linear binning)
    hist_log_bins: bool = False       # beyond-paper: log-spaced bins
    hist_log_min: float = 1e-7        # first log-bin edge (s)
    hist_log_max: float = 10.0        # last log-bin edge (s)
    hist_clear_n: int = 250           # self_clear: reset period (samples)
    ring_n: int = 250                 # circular: ring capacity
    # beyond-paper (the paper's §5 future-work question): exponential
    # recency bias — every insert first scales the port's histogram by
    # ``hist_decay`` (1.0 = off, paper-faithful).  keep_all mode only.
    hist_decay: float = 1.0
    n_r: int = 32                     # PBC shift-register length (<= 32)
    max_tpdt: float = 10e-3           # PBC cap; also no-feasible-bin fallback
    tpdt_init: float = 10e-3          # prediction before history forms
    sync_overhead: float = 5e-9       # §3.1 port-pair sync message cost
    cf_mode: str = "uplift"           # 'uplift': t*(1+cf) | 'scale': t*max(cf,1)
    record_hist: bool = False         # record gaps even for none/fixed (Fig 1)

    def __post_init__(self):
        assert self.kind in ("none", "fixed", "perfbound", "perfbound_correct",
                             "dual", "coalesce", "perfbound_dual",
                             "precoalesce", "predict")
        assert self.sleep_state in EEE_STATES
        assert self.deep_state in EEE_STATES
        assert self.hist_mode in ("keep_all", "self_clear", "circular")
        assert 1 <= self.n_r <= 32
        assert 0.0 < self.hist_decay <= 1.0
        assert self.hist_decay == 1.0 or self.hist_mode == "keep_all", \
            "recency decay composes with keep_all histograms only"
        if self.dual_capable:
            # the ladder must descend: the deep row may only trade a longer
            # wake for a lower power floor
            assert self.deep.t_w >= self.state.t_w \
                and self.deep.power_frac <= self.state.power_frac, \
                "deep_state must not dominate sleep_state"
            assert self.t_dst >= 0.0
        assert self.max_delay >= 0.0 and self.max_frames >= 1
        assert self.hold_delay >= 0.0 and self.hold_frames >= 1
        assert 0.0 <= self.forecast_weight <= 1.0
        assert self.forecast_margin > 0.0
        assert 0.0 < self.period_conf <= 1.0

    @property
    def state(self) -> LinkState:
        return EEE_STATES[self.sleep_state]

    @property
    def deep(self) -> LinkState:
        """The demotion target row (unreachable for single-state kinds)."""
        return EEE_STATES[self.deep_state]

    @property
    def adaptive(self) -> bool:
        return self.kind in ("perfbound", "perfbound_correct",
                             "perfbound_dual", "predict")

    @property
    def dual_capable(self) -> bool:
        """Kinds whose FSM can reach the deep row (second sleep state)."""
        return self.kind in ("dual", "coalesce", "perfbound_dual",
                             "precoalesce", "predict")


# ---------------------------------------------------------------------------
# Static-structure / numeric-parameter split (the batched-sweep contract)
# ---------------------------------------------------------------------------
#
# A Policy factors into
#   * STATIC structure — fields that change compiled code: predictor kind,
#     histogram management mode, array sizes, and boolean feature flags.
#     Policies sharing a static key can run side by side in one compiled
#     batched scan (see repro.core.sweep).
#   * NUMERIC parameters — plain floats the compiled code reads from a
#     parameter vector: timers, bounds, transition times, bin geometry.
#     ``sleep_state`` deliberately lowers to numbers (t_w/t_s/power_frac) —
#     and ``deep_state`` to (t_w2/t_s2/power_frac2), the second row of the
#     FSM state table — so Fast Wake / Deep Sleep / ladder variants of one
#     kind batch together.

# Policy fields that lower to derived numerics rather than appearing in the
# parameter vector under their own name (see policy_params)
_STATE_TABLE_FIELDS = ("t_w", "t_s", "power_frac",
                       "t_w2", "t_s2", "power_frac2")
_LOWERED_FIELDS = ("sleep_state", "deep_state")

PARAM_FIELDS = (
    "t_pdt", "tpdt_init", "max_tpdt", "bound", "sync_overhead",
    "t_w", "t_s", "power_frac",
    "t_w2", "t_s2", "power_frac2", "t_dst",
    "max_delay", "max_frames", "hold_delay", "hold_frames",
    "forecast_weight", "forecast_margin", "period_conf",
    "hist_bin_width", "hist_log_min", "hist_log_max", "hist_clear_n",
    "hist_decay",
)

STATIC_FIELDS = ("kind", "hist_mode", "hist_bins", "hist_log_bins",
                 "ring_n", "n_r", "cf_mode", "record_hist")

# every Policy field must be classified as numeric param, static structure,
# or a state-table name (sleep_state/deep_state, which lower to the
# t_w*/t_s*/power_frac* params) — a field in neither set would be silently
# shared across batch lanes
assert (set(PARAM_FIELDS) - set(_STATE_TABLE_FIELDS)) \
    | set(STATIC_FIELDS) | set(_LOWERED_FIELDS) \
    == {f.name for f in dataclasses.fields(Policy)}, \
    "new Policy field not classified in PARAM_FIELDS/STATIC_FIELDS"


def policy_params(policy: Policy) -> dict:
    """The policy's numeric parameter vector as a plain float dict.

    Passing these back into the simulator/predictor functions reproduces the
    policy exactly; stacking several dicts along a leading axis drives the
    batched sweep.  The FSM state table lowers here: row 1 (t_w/t_s/
    power_frac) from ``sleep_state``, row 2 (t_w2/t_s2/power_frac2) from
    ``deep_state``, and ``t_dst`` pins to +inf for single-state kinds so
    the deep row is numerically unreachable.
    """
    st, st2 = policy.state, policy.deep
    out = {f: float(getattr(policy, f)) for f in PARAM_FIELDS
           if f not in _STATE_TABLE_FIELDS and f != "t_dst"}
    out["t_w"] = st.t_w
    out["t_s"] = st.t_s
    out["power_frac"] = st.power_frac
    out["t_w2"] = st2.t_w
    out["t_s2"] = st2.t_s
    out["power_frac2"] = st2.power_frac
    out["t_dst"] = float(policy.t_dst) if policy.dual_capable \
        else float("inf")
    return out


def static_key(policy: Policy) -> tuple:
    """Hashable static-structure key: policies with equal keys compile to
    the same batched program (numeric params become vector lanes).

    ``hist_decay`` contributes only a boolean (the decay multiply is a
    different program, but its rate is numeric).
    """
    return tuple(getattr(policy, f) for f in STATIC_FIELDS) + \
        (policy.hist_decay < 1.0,)


def canonical_proto(policy: Policy) -> Policy:
    """Reset every numeric field to a fixed value, keeping only static
    structure (plus the ``hist_decay < 1`` program flag).

    The canonical proto is the compile-cache key of the plan executor and
    the batched sweep: policies from the same static group — and chunk
    splits of one group — hash equal, so they reuse ONE compiled program
    and read their numerics lane-wise from a parameter vector.
    """
    return dataclasses.replace(
        policy, sleep_state="deep_sleep", deep_state="deep_sleep",
        t_pdt=0.0, bound=0.01, t_dst=1e-3, max_delay=0.0, max_frames=32,
        hold_delay=0.0, hold_frames=32,
        forecast_weight=0.5, forecast_margin=2.0, period_conf=0.6,
        tpdt_init=10e-3, max_tpdt=10e-3, sync_overhead=5e-9,
        hist_bin_width=10e-6, hist_log_min=1e-7, hist_log_max=10.0,
        hist_clear_n=250,
        hist_decay=0.5 if policy.hist_decay < 1.0 else 1.0)


@dataclass(frozen=True)
class PowerModel:
    """Table 5: system power inventory (W) + link bandwidth."""
    switch_power: float = 250.0
    node_power_min: float = 800.0
    node_power_max: float = 1200.0
    port_power: float = 24.0          # per port-end at Wake
    link_bandwidth: float = 50e9      # bytes/s (400 Gb/s)
    switch_latency: float = 300e-9    # per-hop cut-through latency (s)

    def static_table(self, topo):
        """Reproduces Table 5/6 percentages for a topology.

        Following the paper's convention, each row holds the links AT the
        state's power level while nodes swing between min (idle) and max
        (full load) — i.e. the state's best-case network share bound.
        """
        sw = self.switch_power * topo.n_switches
        links_max = self.port_power * topo.n_ports
        nodes_min = self.node_power_min * topo.n_nodes
        nodes_max = self.node_power_max * topo.n_nodes
        out = {}
        for state_name, frac in [("wake", 1.0)] + [
                (s.name, s.power_frac) for s in EEE_STATES.values()]:
            links_s = links_max * frac
            idle_total = sw + nodes_min + links_s
            full_total = sw + nodes_max + links_s
            out[state_name] = {
                "links_power_idle_W": links_s,
                "network_power_idle_W": sw + links_s,
                "network_of_total_idle": (sw + links_s) / idle_total,
                "network_of_total_full": (sw + links_s) / full_total,
                "links_of_total_idle": links_s / idle_total,
                # the paper's constant 8.68 % column: links all awake under
                # full load, as a share of the full-load system
                "links_of_total_full": links_max
                / (sw + nodes_max + links_max),
                "system_idle_W": idle_total,
                "system_full_W": full_total,
            }
        return out
