"""Coupled network power simulator: a ``lax.scan`` over messages.

Each scan step walks one message along its (<=5-hop) minimal route with a
cut-through timing model, checks/updates every traversed link's EEE FSM
(PDT timers, the Fast Wake -> Deep Sleep demotion ladder, coalescing
deferrals, wake penalties — DESIGN.md §6), feeds the PerfBound predictors,
and integrates per-link wake/sleep/deep-sleep time for energy accounting.

TPU-native layout: per-hop state reads are gathered up front (a message's
route never repeats a link), the 5-hop time chain runs on registers, and all
state writes land as batched scatters.  A dummy row (index P) absorbs writes
from padded/inactive hops so scatters never race.

Execution-time semantics come from the phase-structured replay
(`simulate_trace`): per-node ready times advance across trace steps with
message-delivery dependencies — makespan overhead, packet latency, and energy
are measured exactly as in §4 of the paper.

Replay runs as a compiled two-stage pipeline (DESIGN.md §2): the trace is
compiled ONCE per topology into a device-resident ``TracePlan``
(``repro.traffic.plan``) and executed as ``lax.scan`` over plan steps
(``repro.core.replay``) with the per-node ``ready`` clocks carried on
device.  ``simulate_trace`` is a thin wrapper over that executor (the B=1
case of the batched sweep); the original host step-loop survives as
``simulate_trace_reference`` — the semantic oracle the equivalence suite
(``tests/test_plan.py``) pins the compiled path against.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial, lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import perfbound as pb
from repro.core.eee import Policy, PowerModel
from repro.traffic.plan import compile_plan, pad_message_table

MAX_HOPS = 5


# ---------------------------------------------------------------------------
# Network state
# ---------------------------------------------------------------------------


def init_net(n_links, policy: Policy, params=None):
    P = n_links + 1  # +1 dummy row absorbing masked writes
    # PDT timers are armed at t=0 (ports start awake, counting down) — the
    # same convention as the decoupled per-port replay, so both paths see
    # identical first-arrival semantics.  The demotion deadline sits a
    # (clamped) t_dst past the sleep deadline; for single-state kinds
    # t_dst = +inf keeps the deep row of the FSM unreachable.
    p = pb._params(policy, params)
    dl0 = pb._initial_tpdt(policy, params)
    dl2_0 = dl0 + jnp.maximum(p["t_dst"], p["t_s"])
    net = {
        "dir_free": jnp.zeros((2 * n_links + 1,), jnp.float64),
        "last_end": jnp.zeros((P,), jnp.float64),
        "deadline": jnp.full((P,), dl0, jnp.float64),
        "deadline2": jnp.full((P,), dl2_0, jnp.float64),
        "time_wake": jnp.zeros((P,), jnp.float64),
        "time_sleep": jnp.zeros((P,), jnp.float64),
        "time_sleep2": jnp.zeros((P,), jnp.float64),
        "n_wake": jnp.zeros((P,), jnp.int64),
        "n_hit": jnp.zeros((P,), jnp.int64),
        "n_miss": jnp.zeros((P,), jnp.int64),
        "n_deep": jnp.zeros((P,), jnp.int64),
        "pred": pb.init_state(P, policy, params),
    }
    if policy.kind == "coalesce":
        # per-port coalescing-cycle carry: frames absorbed by the current
        # sleep cycle, the previous cycle's final count (the early-wake
        # burst-size estimate), and the current cycle's wake-completion time
        net["coal_n"] = jnp.zeros((P,), jnp.float64)
        net["coal_prev"] = jnp.zeros((P,), jnp.float64)
        net["coal_release"] = jnp.zeros((P,), jnp.float64)
    if policy.kind == "precoalesce":
        # hold-at-source cycle carry: same structure as coalescing, but the
        # cycle lives on the INJECTION link only — downstream ports see the
        # already-batched bursts and keep plain dual-ladder FSMs
        net["pre_n"] = jnp.zeros((P,), jnp.float64)
        net["pre_prev"] = jnp.zeros((P,), jnp.float64)
        net["pre_release"] = jnp.zeros((P,), jnp.float64)
    return net


# ---------------------------------------------------------------------------
# One message
# ---------------------------------------------------------------------------


def _slot_rows(links, dirs, nhops, valid, n_links):
    """Per-slot row ids: (active mask, link row ``lp``, directed row ``dp``).
    Inactive slots land on the dummy rows (``n_links`` / ``2*n_links``)."""
    H = links.shape[-1]           # route width (Megafly 5, fat-tree 6, ...)
    active = (jnp.arange(H) < nhops[..., None]) & valid[..., None] \
        & (links >= 0)
    lp = jnp.where(active, links, n_links)                 # dummy row when off
    dp = jnp.where(active, 2 * links + dirs, 2 * n_links)
    return active, lp, dp


def _slot_compute(g, msg, active, policy: Policy, pm: PowerModel,
                  params=None):
    """FSM + energy arithmetic of one message (or a batch of link-disjoint
    messages) as a PURE elementwise function of gathered row state.

    ``g`` carries the slot views (same leading shape as ``links``):
    ``free`` (directed occupancy), ``last``/``dl``/``dl2`` (accounting
    frontier + FSM deadlines) and, for the coalescing kinds, the ``coal``
    triple.  Each slot's outputs depend only on its own message's slots
    and its gathered inputs — the serial scatter path and the chained
    wavefront path (replay.py) both consume this, which is what makes
    their results bit-identical by construction (DESIGN.md §10)."""
    links, dirs, nhops, t_inj, nbytes, valid = msg
    H = links.shape[-1]
    p = pb._params(policy, params)
    t_w = p["t_w"] + p["sync_overhead"]
    t_s = p["t_s"]
    # FSM row 2 (Deep Sleep): reachable only past ``deadline2``, which
    # single-state kinds pin to +inf (t_dst = inf) — every row-2 branch
    # below then selects the row-1 value, reproducing the single-state
    # arithmetic bit for bit.
    t_w2 = p["t_w2"] + p["sync_overhead"]
    t_s2 = p["t_s2"]
    coal = policy.kind == "coalesce"
    pre = policy.kind == "precoalesce"
    defer_on = coal or pre
    t_ser = nbytes / pm.link_bandwidth

    free = g["free"]
    last = g["last"]
    dl = g["dl"]
    dl2 = g["dl2"]
    if defer_on:
        # wake deferral for the frame that would wake a sleeping port:
        # full max_delay, scaled down when the previous cycle's burst
        # overran the queue bound (rate estimate of the max_frames
        # trigger).  At a miss the just-ended cycle's count still sits in
        # coal_n (it rolls into coal_prev below), so the freshest burst
        # estimate is coal_n when non-zero, else the rolled coal_prev.
        # precoalesce runs the SAME cycle machinery with its own knobs
        # (hold_delay/hold_frames) on separate carries, restricted below
        # to the injection hop.
        d_delay = p["max_delay"] if coal else p["hold_delay"]
        d_frames = p["max_frames"] if coal else p["hold_frames"]
        coal_n_g, coal_prev_g, coal_release_g = g["coal"]
        prev_burst = jnp.where(coal_n_g > 0, coal_n_g, coal_prev_g)
        defer_full = jnp.where(
            d_frames > 1.0,
            d_delay * d_frames
            / jnp.maximum(prev_burst, d_frames), 0.0)
        # hold-at-source: frames queue at the injection link (hop 0) only;
        # downstream hops never defer
        at_src = jnp.broadcast_to((jnp.arange(H) == 0) if pre
                                  else jnp.ones((H,), bool), active.shape)
        defer_amt = jnp.where(at_src, defer_full, 0.0)

    def _fsm(ta, dl_h, dl2_h, defer_h):
        """One port's FSM read at raw arrival ``ta``: (asleep, deep,
        in_down, in_down2, effective arrival, wake penalty)."""
        asleep = ta >= dl_h
        tae = ta + jnp.where(asleep, defer_h, 0.0) if defer_on else ta
        deep = tae >= dl2_h
        in_down = asleep & (tae < dl_h + t_s)
        in_down2 = deep & (tae < dl2_h + t_s2)
        pen_fast = jnp.where(in_down, dl_h + t_s - tae, 0.0) + t_w
        pen_deep = jnp.where(in_down2, dl2_h + t_s2 - tae, 0.0) + t_w2
        pen = jnp.where(asleep, jnp.where(deep, pen_deep, pen_fast), 0.0)
        return asleep, deep, in_down, in_down2, tae, pen

    # ---- unrolled 5-hop time chain (register-only) -----------------------
    t_head = t_inj
    t_avail = jnp.zeros(active.shape, jnp.float64)
    t_start = jnp.zeros(active.shape, jnp.float64)
    if defer_on:
        # pre-occupancy arrival per hop: the moment the frame reaches the
        # port's queue, BEFORE waiting for the link to free — the time the
        # coalescing-cycle join test must use (a frame queued behind the
        # waking head is serviced after the release, but it joined before)
        t_arr = jnp.zeros(active.shape, jnp.float64)
    delivery = t_inj
    for h in range(H):
        ta = jnp.maximum(t_head, free[..., h])
        _, _, _, _, tae, pen = _fsm(ta, dl[..., h], dl2[..., h],
                                    defer_amt[..., h] if defer_on else 0.0)
        ts_ = tae + pen
        te_ = ts_ + t_ser
        t_avail = t_avail.at[..., h].set(ta)
        t_start = t_start.at[..., h].set(ts_)
        if defer_on:
            t_arr = t_arr.at[..., h].set(t_head)
        t_head = jnp.where(active[..., h], ts_ + pm.switch_latency, t_head)
        delivery = jnp.where(active[..., h], te_, delivery)

    t_end = t_start + t_ser[..., None]
    asleep, deep, in_down, in_down2, tae, _ = _fsm(
        t_avail, dl, dl2, defer_amt if defer_on else 0.0)
    gap = t_avail - last
    new_last = jnp.maximum(last, t_end)

    # ---- energy time integration (frontier scheme) ------------------------
    # ``last_end`` is the accounting frontier: everything before it is
    # already integrated.  awake case: the whole span frontier..t_end is at
    # wake power (idle-awake + transmission); overlap with the opposite
    # direction can make t_end < frontier, in which case nothing is added.
    # asleep case: PDT tail (frontier..deadline) + down transition(s) + wake
    # transition + transmission at wake power; the span between transitions
    # sleeps at the row-1 floor and — past the demotion deadline and its
    # second down transition — at the row-2 floor (zero spans if the packet
    # lands during a down transition).
    wake_fast = (dl - last) + t_s + t_w + t_ser[..., None]
    wake_deep = (dl - last) + t_s + t_s2 + t_w2 + t_ser[..., None]
    wake_add = jnp.where(asleep,
                         jnp.where(deep, wake_deep, wake_fast),
                         jnp.maximum(new_last - last, 0.0))
    sleep_add = jnp.where(asleep & ~in_down,
                          jnp.where(deep, dl2 - (dl + t_s),
                                    jnp.maximum(tae - (dl + t_s), 0.0)),
                          0.0)
    sleep2_add = jnp.where(deep & ~in_down2,
                           jnp.maximum(tae - (dl2 + t_s2), 0.0), 0.0)
    a = active.astype(jnp.float64)

    out = dict(
        active=active, a=a, asleep=asleep, deep=deep, gap=gap,
        t_avail=t_avail, t_start=t_start, t_end=t_end, new_last=new_last,
        wake_add=wake_add, sleep_add=sleep_add, sleep2_add=sleep2_add,
        delivery=delivery,
        lat=jnp.where(valid & (nhops > 0), delivery - t_inj, 0.0),
    )
    if defer_on:
        # precoalesce: the cycle state advances only at the injection hop
        # (the at_src mask); downstream rows write their gathered values
        # back unchanged
        miss = asleep & active & at_src
        join = active & at_src & ~asleep & (coal_n_g > 0) \
            & (t_arr <= coal_release_g)
        roll = jnp.where(coal_n_g > 0, coal_n_g, coal_prev_g)
        out["coal_new"] = (
            jnp.where(miss, 1.0,
                      jnp.where(join, coal_n_g + 1.0, coal_n_g)),
            jnp.where(miss, roll, coal_prev_g),
            jnp.where(miss, t_start, coal_release_g),
        )
    return out


def _message_step(net, msg, policy: Policy, pm: PowerModel, n_links: int,
                  params=None):
    """Advance the net state by one message — or, when the message arrays
    carry a leading batch axis (links ``(m, H)``, scalars ``(m,)``), by a
    whole *wave* of link-disjoint messages at once.  Disjoint routes make
    every gather read rows no other wave member writes and every scatter
    land on distinct rows (the dummy row only ever absorbs masked no-op
    writes), so the batched application is bit-identical to applying the
    members serially in any order (DESIGN.md §10)."""
    links, dirs, nhops, t_inj, nbytes, valid = msg
    p = pb._params(policy, params)
    t_s = p["t_s"]
    coal = policy.kind == "coalesce"
    pre = policy.kind == "precoalesce"
    defer_on = coal or pre
    active, lp, dp = _slot_rows(links, dirs, nhops, valid, n_links)

    g = {
        "free": net["dir_free"][dp],
        "last": net["last_end"][lp],
        "dl": net["deadline"][lp],
        "dl2": net["deadline2"][lp],
    }
    tpdt_prev = net["pred"]["tpdt"][lp]
    if defer_on:
        ck = ("coal_n", "coal_prev", "coal_release") if coal \
            else ("pre_n", "pre_prev", "pre_release")
        g["coal"] = (net[ck[0]][lp], net[ck[1]][lp], net[ck[2]][lp])

    ns = _slot_compute(g, msg, active, policy, pm, params)
    a = ns["a"]
    asleep, deep, gap = ns["asleep"], ns["deep"], ns["gap"]
    t_avail, t_start, t_end = ns["t_avail"], ns["t_start"], ns["t_end"]
    new_last, dl, dl2 = ns["new_last"], g["dl"], g["dl2"]

    net = dict(
        net,
        time_wake=net["time_wake"].at[lp].add(ns["wake_add"] * a),
        time_sleep=net["time_sleep"].at[lp].add(ns["sleep_add"] * a),
        time_sleep2=net["time_sleep2"].at[lp].add(ns["sleep2_add"] * a),
        n_wake=net["n_wake"].at[lp].add((asleep & active).astype(jnp.int64)),
        n_miss=net["n_miss"].at[lp].add((asleep & active).astype(jnp.int64)),
        n_hit=net["n_hit"].at[lp].add((~asleep & active).astype(jnp.int64)),
        n_deep=net["n_deep"].at[lp].add((deep & active).astype(jnp.int64)),
    )

    # ---- coalescing-cycle bookkeeping -------------------------------------
    if defer_on:
        new_n, new_prev, new_release = ns["coal_new"]
        net[ck[1]] = net[ck[1]].at[lp].set(new_prev)
        net[ck[0]] = net[ck[0]].at[lp].set(new_n)
        net[ck[2]] = net[ck[2]].at[lp].set(new_release)

    # ---- occupancy / transmission-end bookkeeping -------------------------
    net["dir_free"] = net["dir_free"].at[dp].add(
        jnp.maximum(t_end - g["free"], 0.0) * a)
    net["last_end"] = net["last_end"].at[lp].add((new_last - g["last"]) * a)

    # ---- predictors --------------------------------------------------------
    H = links.shape[-1]
    pred = net["pred"]
    if policy.adaptive or policy.record_hist:
        pred = pb.record_gaps(pred, lp, gap, t_avail, active, policy, p)
        pred = pb.record_hops(pred, lp, nhops[..., None] - jnp.arange(H),
                              active, policy)
    if policy.kind == "perfbound_correct":
        ratio = gap / jnp.maximum(tpdt_prev, 1e-12)
        pred = pb.record_outcomes(pred, lp, asleep, ratio, active, policy)
    if policy.adaptive:
        if policy.kind == "perfbound_dual":
            new_tpdt, new_tdst = pb.compute_tpdt_tdst(
                pred, lp, t_end, p["t_w"], policy, p)
            pred = dict(pred, t_dst=pred["t_dst"].at[lp].set(
                jnp.where(active, new_tdst, pred["t_dst"][lp])))
        elif policy.kind == "predict":
            new_tpdt, new_tdst, new_ewma = pb.forecast_update(
                pred, lp, gap, active, policy, p)
            pred = dict(
                pred,
                t_dst=pred["t_dst"].at[lp].set(
                    jnp.where(active, new_tdst, pred["t_dst"][lp])),
                ewma=pred["ewma"].at[lp].set(
                    jnp.where(active, new_ewma, pred["ewma"][lp])))
        else:
            new_tpdt = pb.compute_tpdt(pred, lp, t_end, p["t_w"], policy, p)
        pred = dict(pred, tpdt=pred["tpdt"].at[lp].set(
            jnp.where(active, new_tpdt, pred["tpdt"][lp])))
    net["pred"] = pred

    # deadline = end of PDT countdown after the latest transmission;
    # deadline2 = the demotion point a (clamped) t_dst further out
    tpdt_now = net["pred"]["tpdt"][lp]
    new_dl = jnp.where(active, new_last + tpdt_now, dl)
    net["deadline"] = net["deadline"].at[lp].add(new_dl - dl)
    tdst_now = net["pred"]["t_dst"][lp] \
        if policy.kind in ("perfbound_dual", "predict") else p["t_dst"]
    new_dl2 = jnp.where(active, new_dl + jnp.maximum(tdst_now, t_s), dl2)
    # masked SET, not scatter-add: adaptive t_dst legitimately swings
    # between +inf ("never demote") and finite, and inf - inf through an
    # add would latch the row at NaN, silently disabling demotion forever
    net["deadline2"] = net["deadline2"].at[lp].set(new_dl2)

    events = (lp, t_start, t_end, active)
    return net, (ns["delivery"], ns["lat"], events)


def chain_spec(policy: Policy):
    """Row-state layout for the CHAINED wavefront executor (replay.py):
    ``(f64 lp-keyed keys, i64 lp-keyed keys)`` — every per-link row array
    the message phase reads or writes, excluding ``dir_free`` (dp-keyed,
    threaded separately) and ``pred.tpdt`` (read-only for these kinds).

    Returns ``None`` for the adaptive / histogram-recording kinds: their
    predictor state (histogram matrices, ring buffers, shift registers) is
    not threaded through the chain buffers, so those protos fall back to
    the scatter-per-wave batched loop."""
    if policy.adaptive or policy.record_hist:
        return None
    f64 = ["last_end", "deadline", "deadline2",
           "time_wake", "time_sleep", "time_sleep2"]
    if policy.kind == "coalesce":
        f64 += ["coal_n", "coal_prev", "coal_release"]
    if policy.kind == "precoalesce":
        f64 += ["pre_n", "pre_prev", "pre_release"]
    i64 = ["n_wake", "n_miss", "n_hit", "n_deep"]
    return tuple(f64), tuple(i64)


@lru_cache(maxsize=None)
def _compiled_chunk(policy: Policy, pm: PowerModel, n_links: int,
                    collect_events: bool):
    @partial(jax.jit, donate_argnums=(0,))
    def run(net, msgs):
        def step(net, m):
            net, (d, lat, ev) = _message_step(net, m, policy, pm, n_links)
            out = (d, lat, ev) if collect_events else (d, lat)
            return net, out
        return lax.scan(step, net, msgs)
    return run


def sim_chunk(net, msgs, policy, pm, n_links, collect_events=False):
    """msgs: tuple of arrays (links (M,5), dirs, nhops, t_inj, bytes, valid)."""
    return _compiled_chunk(policy, pm, n_links, collect_events)(net, msgs)


# ---------------------------------------------------------------------------
# Close-out + energy summary
# ---------------------------------------------------------------------------


def close_out(net, t_end_sim, policy: Policy, n_links: int):
    """Integrate every link's tail (last transmission .. end of sim) at the
    FSM row it ends in: awake, row-1 sleep past ``deadline``, row-2 sleep
    past ``deadline2`` (never reached by single-state kinds).  Returns
    (time_wake, time_sleep, time_sleep2)."""
    st, st2 = policy.state, policy.deep
    # jnp inputs throughout: the multi-trace readback hands numpy views in,
    # and raw numpy would warn on the (masked-away) inf-inf deep spans of
    # never-woken links
    last = jnp.asarray(net["last_end"][:n_links])
    dl = jnp.asarray(net["deadline"][:n_links])
    dl2 = jnp.asarray(net["deadline2"][:n_links])
    t_end_sim = jnp.maximum(t_end_sim, last.max())
    sleeps = dl + st.t_s < t_end_sim
    deeps = dl2 + st2.t_s < t_end_sim
    # elapsed part of the second down transition (wake power, like every
    # transition): full t_s2 once demoted, partial if the sim ends
    # mid-transition, 0 for single-state rows (dl2 = +inf)
    down2 = jnp.clip(t_end_sim - dl2, 0.0, st2.t_s)
    wake_extra = jnp.where(
        sleeps, (dl - last) + st.t_s + down2, t_end_sim - last)
    sleep_extra = jnp.where(
        sleeps, jnp.where(deeps, dl2 - (dl + st.t_s),
                          jnp.minimum(t_end_sim, dl2) - dl - st.t_s), 0.0)
    sleep2_extra = jnp.where(deeps, t_end_sim - dl2 - st2.t_s, 0.0)
    return (net["time_wake"][:n_links] + jnp.maximum(wake_extra, 0.0),
            net["time_sleep"][:n_links] + jnp.maximum(sleep_extra, 0.0),
            net["time_sleep2"][:n_links] + jnp.maximum(sleep2_extra, 0.0))


@dataclass
class SimResult:
    makespan: float
    mean_latency: float
    max_latency: float
    n_messages: int
    link_energy: float
    switch_energy: float
    node_energy: float
    total_energy: float
    asleep_frac: float          # mean fraction of time links spent asleep
    deep_frac: float            # fraction of link time in the deep FSM row
    n_wake_transitions: int
    hits: int
    misses: int
    deep_misses: int            # arrivals that found their port demoted

    def as_dict(self):
        return dataclasses.asdict(self)


def summarize(net, t_end, busy_node_secs, lat_sum, lat_max, n_msgs,
              policy: Policy, pm: PowerModel, topo) -> SimResult:
    tw, ts_, ts2 = close_out(net, t_end, policy, topo.n_links)
    frac = policy.state.power_frac
    frac2 = policy.deep.power_frac
    link_e = float(2 * pm.port_power
                   * (tw.sum() + frac * ts_.sum() + frac2 * ts2.sum()))
    switch_e = float(pm.switch_power * topo.n_switches * t_end)
    node_e = float(pm.node_power_min * topo.n_nodes * t_end
                   + (pm.node_power_max - pm.node_power_min) * busy_node_secs)
    total_t = tw.sum() + ts_.sum() + ts2.sum()
    return SimResult(
        makespan=float(t_end),
        mean_latency=float(lat_sum / max(n_msgs, 1)),
        max_latency=float(lat_max),
        n_messages=int(n_msgs),
        link_energy=link_e,
        switch_energy=switch_e,
        node_energy=node_e,
        total_energy=link_e + switch_e + node_e,
        asleep_frac=float((ts_.sum() + ts2.sum())
                          / jnp.maximum(total_t, 1e-30)),
        deep_frac=float(ts2.sum() / jnp.maximum(total_t, 1e-30)),
        n_wake_transitions=int(net["n_wake"][:topo.n_links].sum()),
        hits=int(net["n_hit"][:topo.n_links].sum()),
        misses=int(net["n_miss"][:topo.n_links].sum()),
        deep_misses=int(net["n_deep"][:topo.n_links].sum()),
    )


# ---------------------------------------------------------------------------
# Phase-structured trace replay (execution-time semantics)
# ---------------------------------------------------------------------------


def _pad_msgs(links, dirs, nhops, t_inj, nbytes, bucket_min=64):
    """Serial front-end of the shared padder: host arrays in, device
    ``(links, dirs, nhops, t_inj, nbytes, valid)`` tuple out."""
    out = pad_message_table(links, dirs, nhops, t_inj, nbytes,
                            bucket_min=bucket_min)
    return tuple(jnp.asarray(a) for a in out)


def simulate_trace(trace, topo, policy: Policy, pm: PowerModel | None = None,
                   collect_events=False):
    """Replay a Trace (see repro.traffic.trace) under a policy.

    Runs on the compiled plan pipeline: ``repro.traffic.plan.compile_plan``
    (cached per (trace, topo)) + the ``repro.core.replay`` scan executor,
    as the B=1 case of the batched sweep engine.  Results match the host
    step-loop reference (``simulate_trace_reference``) to float64
    tolerance — enforced by ``tests/test_plan.py``.

    Returns (SimResult, events) — events is a list of per-step host arrays
    (link, t_start, t_end) when collect_events, else None.
    """
    from repro.core import replay  # late: replay imports us
    pm = pm or PowerModel()
    plan = compile_plan(trace, topo)
    nets, t_end, lat_sum, lat_max, seg_events = replay.replay_plan(
        plan, [policy], pm, collect_events)
    net0 = jax.tree.map(lambda x: x[0], nets)
    res = summarize(net0, float(t_end[0]), plan.busy, float(lat_sum[0]),
                    float(lat_max[0]), plan.n_msgs, policy, pm, topo)
    events = (replay.events_to_host(plan, seg_events) if collect_events
              else None)
    return res, events


def simulate_trace_reference(trace, topo, policy: Policy,
                             pm: PowerModel | None = None,
                             collect_events=False):
    """Host step-loop replay — the semantic oracle for the compiled path.

    One ``sim_chunk`` dispatch per trace step with host-side injection
    sorting, route lookup and ``ready``-clock bookkeeping.  Slower than
    ``simulate_trace`` (per-step host<->device ping-pong) but with no plan
    compilation: the equivalence suite replays both and compares.
    """
    pm = pm or PowerModel()
    net = init_net(topo.n_links, policy)
    ready = np.zeros(topo.n_nodes, np.float64)
    busy = 0.0
    lat_sum, lat_max, n_msgs = 0.0, 0.0, 0
    all_events = [] if collect_events else None

    for step in trace.steps:
        if step.compute_nodes is not None and len(step.compute_nodes):
            ready[step.compute_nodes] += step.compute_secs
            busy += float(step.compute_secs.sum())
        if step.msgs is not None and len(step.msgs):
            src = step.msgs[:, 0]
            dst = step.msgs[:, 1]
            nbytes = step.msgs[:, 2].astype(np.float64)
            t_inj = ready[src]
            order = np.argsort(t_inj, kind="stable")
            src, dst, nbytes, t_inj = (src[order], dst[order],
                                       nbytes[order], t_inj[order])
            links, dirs, nhops = topo.routes(src, dst)
            msgs = _pad_msgs(links, dirs, nhops, t_inj, nbytes)
            net, out = sim_chunk(net, msgs, policy, pm, topo.n_links,
                                 collect_events)
            delivery = np.asarray(out[0])[: len(src)]
            lat = np.asarray(out[1])[: len(src)]
            np.maximum.at(ready, dst, delivery)
            lat_sum += float(lat.sum())
            lat_max = max(lat_max, float(lat.max(initial=0.0)))
            n_msgs += len(src)
            if collect_events:
                lp, ts_, te_, act = (np.asarray(x) for x in out[2])
                m = act[: len(src)].astype(bool)
                all_events.append((lp[: len(src)][m], ts_[: len(src)][m],
                                   te_[: len(src)][m]))
        if step.barrier:
            nodes = trace.nodes
            ready[nodes] = ready[nodes].max()

    t_end = float(ready[trace.nodes].max()) if len(trace.nodes) else 0.0
    res = summarize(net, t_end, busy, lat_sum, lat_max, n_msgs,
                    policy, pm, topo)
    return res, all_events


def unused_key(mapping: dict, base: str = "__baseline__") -> str:
    """A key not present in ``mapping`` (prefixing underscores as needed) —
    lets a hidden baseline lane ride in a user-named policy grid."""
    while base in mapping:
        base = "_" + base
    return base


def relative_rows(base: SimResult, results: dict,
                  baseline: str = "baseline") -> dict:
    """The §4 table protocol: each result as a dict row with overhead /
    saving percentages vs ``base`` (which leads the rows, reporting
    zeros).  Degenerate baselines (empty traces) report 0 instead of
    dividing by zero.  Shared by ``compare_policies`` and the scenario
    suite (``repro.scenarios.suite``)."""
    out = {baseline: dict(base.as_dict(), exec_overhead_pct=0.0,
                          latency_overhead_pct=0.0, energy_saved_pct=0.0,
                          link_energy_saved_pct=0.0)}
    for name, r in results.items():
        out[name] = dict(
            r.as_dict(),
            exec_overhead_pct=100 * (r.makespan / base.makespan - 1)
            if base.makespan else 0.0,
            latency_overhead_pct=100 * (r.mean_latency / base.mean_latency - 1)
            if base.mean_latency else 0.0,
            energy_saved_pct=100 * (1 - r.total_energy / base.total_energy)
            if base.total_energy else 0.0,
            link_energy_saved_pct=100 * (1 - r.link_energy / base.link_energy)
            if base.link_energy else 0.0,
        )
    return out


def compare_policies(trace, topo, policies: dict, pm: PowerModel | None = None,
                     baseline: str = "baseline",
                     max_group: int | None = None):
    """Run a trace under several policies; report overheads vs the baseline
    (always-on) run — the paper's evaluation protocol (§4).

    Runs on the batched sweep engine (``repro.core.sweep``): policies
    sharing static structure replay the trace together in one compiled
    scan per chunk instead of once each.
    """
    from repro.core.sweep import sweep_policies  # late: sweep imports us
    pm = pm or PowerModel()
    base_key = unused_key(policies)
    results = sweep_policies(trace, topo,
                             {base_key: Policy(kind="none"), **policies},
                             pm, max_group=max_group)
    base = results.pop(base_key)
    return relative_rows(base, results, baseline)
