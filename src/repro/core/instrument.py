"""Lightweight JAX instrumentation: compile counting for benches + tests.

``count_compiles()`` taps ``jax.monitoring`` for backend-compile events so
the benchmark driver can report how many XLA programs a run built (the
perf-trajectory JSON in ``benchmarks/run.py``) and the test-suite can
assert that warm plan replays compile NOTHING.  Transfer elimination is
pinned separately with ``jax.transfer_guard`` (see tests/test_plan.py).
"""
from __future__ import annotations

import contextlib

import jax.monitoring

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_state = {"installed": False, "n": 0}


def _on_event(event, duration, **_kw):
    if event == _COMPILE_EVENT:
        _state["n"] += 1


def _install():
    if not _state["installed"]:
        jax.monitoring.register_event_duration_secs_listener(_on_event)
        _state["installed"] = True


class CompileCount:
    def __init__(self, start):
        self._start = start

    @property
    def count(self) -> int:
        return _state["n"] - self._start


@contextlib.contextmanager
def count_compiles():
    """Context manager yielding a live backend-compile counter:

        with count_compiles() as cc:
            ...
        print(cc.count)
    """
    _install()
    yield CompileCount(_state["n"])
