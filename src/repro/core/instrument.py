"""Lightweight JAX instrumentation: compile counting for benches + tests.

``count_compiles()`` taps ``jax.monitoring`` for backend-compile events so
the benchmark driver can report how many XLA programs a run built (the
perf-trajectory JSON in ``benchmarks/run.py``) and the test-suite can
assert that warm plan replays compile NOTHING.  ``compile_guard()`` turns
that assertion into a hard runtime error for regions that MUST stay
program-cache-hot (warm auto-tuner rounds, warm bench passes).  Transfer
elimination is pinned separately with ``jax.transfer_guard`` (see
tests/test_plan.py).
"""
from __future__ import annotations

import contextlib

import jax.monitoring

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_state = {"installed": False, "n": 0}


def _on_event(event, duration, **_kw):
    if event == _COMPILE_EVENT:
        _state["n"] += 1


def _install():
    if not _state["installed"]:
        jax.monitoring.register_event_duration_secs_listener(_on_event)
        _state["installed"] = True


class CompileCount:
    def __init__(self, start):
        self._start = start

    @property
    def count(self) -> int:
        return _state["n"] - self._start


@contextlib.contextmanager
def count_compiles():
    """Context manager yielding a live backend-compile counter:

        with count_compiles() as cc:
            ...
        print(cc.count)
    """
    _install()
    yield CompileCount(_state["n"])


class CompileGuardError(RuntimeError):
    """A guarded region built more XLA programs than its budget allows."""


@contextlib.contextmanager
def compile_guard(what: str = "guarded region", budget: int = 0):
    """Fail loudly when a region compiles more than ``budget`` programs.

    The hard-error sibling of ``count_compiles`` for code paths whose whole
    point is program-cache reuse: warm replay loops, the auto-tuner's warm
    refinement rounds.  Yields the live counter so callers can also record
    the observed count.
    """
    with count_compiles() as cc:
        yield cc
    if cc.count > budget:
        raise CompileGuardError(
            f"{what} compiled {cc.count} XLA programs "
            f"(budget {budget}) — a plan/program cache went cold")
