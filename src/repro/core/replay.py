"""Compiled trace replay: execute a TracePlan as ``lax.scan`` over steps.

Stage 2 of the plan/execute split (DESIGN.md §2).  The executor carries
(``net`` EEE/predictor state, per-node ``ready`` clocks, latency
accumulators) entirely on device across the whole trace:

  * injection-time ordering runs as a **stable ``jnp.argsort`` inside the
    scanned step** (per batch lane — each policy's latency feedback gives
    it a different replay order), replacing the per-step host sorts;
  * delivery maxima update ``ready`` via **scatter-max** (invalid slots
    carry -inf, so padding never races);
  * compute advances and barriers are **scan-step branches**: a dense
    per-step clock delta plus a masked participant-max select;
  * message-less steps skip the message machinery through a ``lax.cond``
    on the plan's per-step ``has_msgs`` flag.

The serial engine is the B=1 case of the batched one: ``policies`` lanes
share a canonical static proto (``eee.canonical_proto``) and read their
numerics lane-wise from a stacked parameter vector, so one compiled
program serves every policy of a static group — and every B — per segment
shape.  The ``net`` carry is an opaque pytree to this layer: the FSM
fields the dual-mode kinds add (``deadline2``/``time_sleep2``/``n_deep``,
plus the coalescing-cycle state of the ``coalesce`` kind — DESIGN.md §6)
vmap over the B policy axis and the T trace axis like every other entry,
with no executor changes.  Between segments only jitted-call dispatch happens on host; the
carry never leaves the device (``tests/test_plan.py`` pins this with a
``jax.transfer_guard``).
"""
from __future__ import annotations

from contextlib import contextmanager
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import simulator as S
from repro.core.eee import (PARAM_FIELDS, Policy, PowerModel,
                            canonical_proto, policy_params)


def stack_params(pols) -> dict:
    """Stack each policy's numeric parameter vector into (B,) f64 arrays."""
    cols = [policy_params(p) for p in pols]
    return {f: jnp.asarray([c[f] for c in cols], jnp.float64)
            for f in PARAM_FIELDS}


# ---------------------------------------------------------------------------
# Wavefront mode selection (DESIGN.md §10)
# ---------------------------------------------------------------------------

#: ``off`` pins the classic length-``cap`` serial scan; ``on`` runs the
#: plan-scheduled message phase (best of prefix / chained waves per
#: segment); ``auto`` additionally keeps the scan when the cost model
#: predicts no win.  ``prefix`` / ``chain`` force one lowering (tests).
WAVEFRONT_MODES = ("auto", "on", "off", "prefix", "chain")
_WAVEFRONT = "auto"

#: Per-segment executor cost model (CPU XLA, microseconds per message
#: step at cap 64 / B 1 — DESIGN.md §10).  The serial scan walks every
#: padded slot; the prefix loop runs only the step's LIVE slots (plus the
#: per-step sort/dispatch fixed cost); a chained wave's marginal cost is
#: two chain gathers + dense slot math, with the chain setup (argsorts,
#: row gathers, final scatters) as a fixed per-step term.
SCAN_SLOT_US = 9.0
PREFIX_FIXED_US = 125.0
PREFIX_SLOT_US = 8.0
CHAIN_FIXED_US = 340.0
WAVE_US = 23.0


def set_wavefront(mode: str) -> None:
    """Select the message-phase executor mode.  All modes produce
    bit-identical results; only wall-clock differs."""
    global _WAVEFRONT
    assert mode in WAVEFRONT_MODES, \
        f"wavefront mode {mode!r} not in {WAVEFRONT_MODES}"
    _WAVEFRONT = mode


@contextmanager
def wavefront_mode(mode: str):
    """Scoped :func:`set_wavefront`."""
    prev = _WAVEFRONT
    set_wavefront(mode)
    try:
        yield
    finally:
        set_wavefront(prev)


def phase_costs(seg, proto: Policy) -> dict:
    """Predicted per-message-step cost (µs) of each executor lowering for
    one segment, from the plan's host metadata: mean live count (prefix
    trip), mean canonical wave count (chain trip), and the static cap
    (scan trip).  ``chain`` is absent for protos outside
    :func:`S.chain_spec` — their fallback wave loop re-scatters every cap
    slot per wave and never wins."""
    costs = {"scan": SCAN_SLOT_US * seg.cap,
             "prefix": PREFIX_FIXED_US + PREFIX_SLOT_US * seg.mean_live}
    if S.chain_spec(proto) is not None:
        costs["chain"] = CHAIN_FIXED_US + WAVE_US * seg.mean_wave
    return costs


def _phase_mode(seg, proto: Policy, collect_events: bool = False) -> str:
    """Executor lowering for one segment: ``scan``, ``prefix`` or
    ``chain``.  Event collection always runs the serial scan (events
    stack per-message in replay order)."""
    if seg.cap == 0 or collect_events or _WAVEFRONT == "off":
        return "scan"
    if _WAVEFRONT in ("prefix", "chain"):
        return _WAVEFRONT
    costs = phase_costs(seg, proto)
    if _WAVEFRONT == "on":
        del costs["scan"]            # forced: never the serial scan
    return min(costs, key=costs.get)


def _seg_flags(seg, proto: Policy, collect_events: bool = False) -> tuple:
    """(mode, needs_sort) runner flags, canonicalized so message-less
    segments share one program key."""
    if not seg.cap:
        return "scan", True
    return _phase_mode(seg, proto, collect_events), seg.needs_sort


# ---------------------------------------------------------------------------
# Compiled per-segment runner
# ---------------------------------------------------------------------------


def _row_chain(rows):
    """Per-slot predecessor chain of one step's flat slot->row mapping.

    ``pred[k]`` is the latest earlier slot writing the same row (self when
    none); ``last[k]`` marks the row's final writer.  ONE stable argsort
    groups each row's slots in slot order, so following ``pred`` replays a
    row's writers in exactly the serial execution order — this is the whole
    conflict structure the chained wavefront executor needs, at O(K log K)
    instead of the O(K^2) pairwise conflict matrix."""
    K = rows.shape[0]
    ordi = jnp.argsort(rows, stable=True)
    inv = jnp.argsort(ordi)
    r_s = rows[ordi]
    same_prev = jnp.concatenate(
        [jnp.zeros((1,), bool), r_s[1:] == r_s[:-1]])
    prev_slot = jnp.concatenate([ordi[:1], ordi[:-1]])
    pred_s = jnp.where(same_prev, prev_slot, ordi)
    last_s = jnp.concatenate([r_s[1:] != r_s[:-1], jnp.ones((1,), bool)])
    return pred_s[inv], last_s[inv]


def _conflicts(links, nhops, valid):
    """(cap, cap) bool conflict matrix of one step's messages, on device.

    Messages conflict iff their active hop link sets intersect (they touch
    a shared per-link FSM row).  Computed in UNSORTED slot space from the
    plan's static route arrays — lane-invariant, so the step computes it
    once outside the B vmap and each lane permutes it into its own
    injection order with ``conf[order][:, order]``."""
    cap, H = links.shape
    hop_ok = (links >= 0) & (jnp.arange(H) < nhops[:, None]) & valid[:, None]
    eq = links[:, None, :, None] == links[None, :, None, :]
    ok = hop_ok[:, None, :, None] & hop_ok[None, :, None, :]
    conf = (eq & ok).reshape(cap, cap, H * H).any(-1)
    return conf & ~jnp.eye(cap, dtype=bool)


def _make_run(proto: Policy, pm: PowerModel, n_links: int, cap: int,
              collect_events: bool, mode: str = "scan",
              needs_sort: bool = True):
    """Build the (un-jitted) per-trace segment program: one ``lax.scan``
    over a segment's steps with B policy lanes vmapped inside the step.

    ``_segment_runner`` jits it directly (the single-trace path);
    ``_multi_segment_runner`` vmaps it once more over a leading trace axis
    (the ``PlanBatch`` path) — same step arithmetic, so per-lane results
    are bit-identical between the two.

    ``mode`` selects the message-phase lowering (DESIGN.md §10), all
    bit-identical to the ``scan`` baseline:

    * ``"scan"`` — the classic length-``cap`` serial inner scan;
    * ``"prefix"`` — a dynamic loop over the step's VALID slot prefix
      (trip = the plan's per-step live count, not the padded cap);
    * ``"chain"`` — conflict-free waves over the per-row predecessor
      chain, each wave one batch of dense slot math.

    ``needs_sort=False`` drops the per-step stable argsort for segments
    whose steps statically carry <=1 valid message (valid slots are a
    prefix, so the sort is the identity there)."""
    assert mode == "scan" or not collect_events, \
        "event collection requires the serial message scan"
    spec = S.chain_spec(proto) if mode == "chain" else None
    chained = spec is not None

    def _wave_chain(net, p, msgs, valid_s):
        """Message phase as a CHAINED wave loop for ONE policy lane.

        The scatter-bound cost model of the batched wave loop (every wave
        re-scatters all ``cap`` slots) is turned inside out: row state is
        gathered ONCE per step into per-slot buffers, each wave runs the
        pure :func:`S._slot_compute` arithmetic on values read through the
        per-slot predecessor chain (``_row_chain``), and each row's final
        value is scattered back ONCE by its last-writer slot.  Per-wave
        work is dense vector math + two chain gathers — no scatters.

        Bit-identity with the serial scan holds by construction: a slot's
        chain input IS the value the serial path would gather (its row
        after the previous writer), and every update replicates the serial
        scatter arithmetic operand-for-operand (adds stay ``g + delta``,
        sets stay masked selects).  Dummy-row slots chain among themselves
        and land on the dummy row, which both paths already treat as
        garbage (masked adds of NaN deltas)."""
        links_s, dirs_s, nhops_s, t_inj_s, nbytes_s, _ = msgs
        cap, H = links_s.shape
        K = cap * H
        f64_keys, i64_keys = spec
        Lf, Li = len(f64_keys), len(i64_keys)
        kf = {k: i for i, k in enumerate(f64_keys)}
        ck = None
        if proto.kind == "coalesce":
            ck = ("coal_n", "coal_prev", "coal_release")
        elif proto.kind == "precoalesce":
            ck = ("pre_n", "pre_prev", "pre_release")
        active, lp, dp = S._slot_rows(links_s, dirs_s, nhops_s, valid_s,
                                      n_links)
        lpf, dpf, afl = lp.reshape(K), dp.reshape(K), active.reshape(K)
        predL, lastL = _row_chain(lpf)
        predD, lastD = _row_chain(dpf)

        if needs_sort:
            # per-hop chain predecessors (message index): a slot is ready
            # when every predecessor message has executed.  Same-message
            # predecessors (a route revisiting a link) are masked out —
            # hops of one message share a wave by definition.
            own = jnp.arange(cap)[:, None]
            pmm = (predL // H).reshape(cap, H)
            hp = (predL != jnp.arange(K)).reshape(cap, H) & active \
                & (pmm != own)
        else:
            pmm = jnp.zeros((cap, H), predL.dtype)
            hp = jnp.zeros((cap, H), bool)

        # one gather per dtype group: stacked row arrays -> slot views
        RF = jnp.stack([net[k] for k in f64_keys])          # (Lf, P)
        RI = jnp.stack([net[k] for k in i64_keys])          # (Li, P)
        GF = RF[:, lp]                                      # (Lf, cap, H)
        GD = net["dir_free"][dp]
        tpdt0 = net["pred"]["tpdt"][lp]     # read-only for chained kinds
        t_s = p["t_s"]
        tdst = jnp.maximum(p["t_dst"], t_s)

        def body(st):
            VF, VD, cI, delivery, lat, done = st
            # frontier membership == the order-preserving wave schedule:
            # ready slots whose chain predecessors have all executed
            member = ~done & jnp.where(hp, done[pmm], True).all(axis=1)
            act = active & member[:, None]
            inF = VF[:, predL].reshape((Lf, cap, H))
            inD = VD[predD].reshape((cap, H))
            g = {"free": inD, "last": inF[kf["last_end"]],
                 "dl": inF[kf["deadline"]], "dl2": inF[kf["deadline2"]]}
            if ck is not None:
                g["coal"] = (inF[kf[ck[0]]], inF[kf[ck[1]]],
                             inF[kf[ck[2]]])
            m = (links_s, dirs_s, nhops_s, t_inj_s, nbytes_s, member)
            ns = S._slot_compute(g, m, act, proto, pm, params=p)
            a = ns["a"]
            asleep, deep = ns["asleep"], ns["deep"]
            new_last = ns["new_last"]
            # new row values, replicating the serial scatter arithmetic
            # operand-for-operand: .add -> g + delta, .set -> masked select
            updF = [None] * Lf
            updF[kf["last_end"]] = g["last"] + (new_last - g["last"]) * a
            new_dl = jnp.where(act, new_last + tpdt0, g["dl"])
            updF[kf["deadline"]] = g["dl"] + (new_dl - g["dl"])
            updF[kf["deadline2"]] = jnp.where(act, new_dl + tdst, g["dl2"])
            updF[kf["time_wake"]] = inF[kf["time_wake"]] \
                + ns["wake_add"] * a
            updF[kf["time_sleep"]] = inF[kf["time_sleep"]] \
                + ns["sleep_add"] * a
            updF[kf["time_sleep2"]] = inF[kf["time_sleep2"]] \
                + ns["sleep2_add"] * a
            if ck is not None:
                updF[kf[ck[0]]], updF[kf[ck[1]]], updF[kf[ck[2]]] = \
                    ns["coal_new"]
            # int counters are pure commutative adds — no chaining needed:
            # record each slot's contribution once, scatter-add at the end
            contrib = jnp.stack([asleep & act, asleep & act,
                                 ~asleep & act, deep & act]
                                ).astype(jnp.int64).reshape((Li, K))
            updD = inD + jnp.maximum(ns["t_end"] - inD, 0.0) * a
            mK = jnp.repeat(member, H)
            VF = jnp.where(mK[None], jnp.stack(updF).reshape((Lf, K)), VF)
            cI = jnp.where(mK[None], contrib, cI)
            VD = jnp.where(mK, updD.reshape(K), VD)
            return (VF, VD, cI,
                    jnp.where(member, ns["delivery"], delivery),
                    jnp.where(member, ns["lat"], lat), done | member)

        VF, VD, cI, delivery, lat, _ = lax.while_loop(
            lambda st: ~st[5].all(), body,
            (GF.reshape((Lf, K)), GD.reshape(K),
             jnp.zeros((Li, K), jnp.int64),
             t_inj_s, jnp.zeros_like(t_inj_s), ~valid_s))

        # ONE scatter per dtype group: each row's last writer carries its
        # final value; every other slot redirects to the dummy row (already
        # garbage-tolerated by the serial path's masked scatters)
        idxL = jnp.where(afl & lastL, lpf, n_links)
        idxD = jnp.where(afl & lastD, dpf, 2 * n_links)
        RF = RF.at[:, idxL].set(VF)
        RI = RI.at[:, jnp.where(afl, lpf, n_links)].add(cI)
        net = dict(net, dir_free=net["dir_free"].at[idxD].set(VD))
        for i, k in enumerate(f64_keys):
            net[k] = RF[i]
        for i, k in enumerate(i64_keys):
            net[k] = RI[i]
        return net, delivery, lat

    def _wave_phase(net, p, msgs, valid_s, conf, order):
        """Message phase as a dynamic wave loop for ONE policy lane.

        Wave ids follow the ORDER-PRESERVING recurrence
        ``wave[i] = 1 + max(wave[j] : j conflicts i, j before i)`` (1-based
        over valid slots), solved by fixpoint iteration: conflicting pairs
        land in strictly increasing waves matching the injection sort, so
        every FSM row sees its messages in exactly the serial order."""
        links_s, dirs_s, nhops_s, t_inj_s, nbytes_s, _ = msgs
        n = valid_s.shape[0]
        if order is not None:
            conf_s = conf[order][:, order]
            pred = conf_s & (jnp.arange(n)[None, :] < jnp.arange(n)[:, None])

            def fixed(st):
                wv, _ = st
                nw = jnp.where(
                    valid_s,
                    jnp.where(pred, wv[None, :], 0).max(axis=1) + 1,
                    0).astype(jnp.int32)
                return nw, (nw != wv).any()

            wave, _ = lax.while_loop(lambda st: st[1], fixed,
                                     (valid_s.astype(jnp.int32),
                                      jnp.array(True)))
        else:
            # needs_sort=False: <=1 valid message, trivially one wave
            wave = valid_s.astype(jnp.int32)
        wmax = wave.max()

        def body(st):
            net, delivery, lat, w = st
            member = valid_s & (wave == w)
            net, (d, l, _ev) = S._message_step(
                net, (links_s, dirs_s, nhops_s, t_inj_s, nbytes_s, member),
                proto, pm, n_links, params=p)
            return (net, jnp.where(member, d, delivery),
                    jnp.where(member, l, lat), w + 1)

        # dynamic trip count = the step's realized wave width; under vmap
        # this lifts to the max over lanes and converged lanes run all-
        # masked (provably no-op) extra waves
        net, delivery, lat, _ = lax.while_loop(
            lambda st: st[3] <= wmax, body,
            (net, t_inj_s, jnp.zeros_like(t_inj_s), jnp.int32(1)))
        return net, delivery, lat

    def _prefix_phase(net, p, msgs, valid_s, nv):
        """Message phase as a dynamic loop over the step's VALID prefix.

        After the injection sort the valid slots are a prefix of length
        ``nv`` (the plan's per-step live count, ``xs["live"]``), while
        ``cap`` is the segment-wide bucket — ``BUCKET_MIN`` or a power of
        two, often several times larger.  The serial scan burns a full
        ``cap`` trip on provably no-op padding slots; this loop runs the
        SAME per-message body ``nv`` times and stops.  Skipped padding
        iterations only touch the dummy rows both paths treat as garbage
        (masked scatters of zero/NaN deltas), so results are bit-identical
        to the scan."""
        links_s, dirs_s, nhops_s, t_inj_s, nbytes_s, _ = msgs

        def body(st):
            net, delivery, lat, i = st
            m = tuple(lax.dynamic_index_in_dim(v, i, keepdims=False)
                      for v in (links_s, dirs_s, nhops_s, t_inj_s,
                                nbytes_s, valid_s))
            net, (d, l, _ev) = S._message_step(net, m, proto, pm, n_links,
                                               params=p)
            return (net, delivery.at[i].set(d), lat.at[i].set(l), i + 1)

        # padding slots never deliver (masked out of the ready scatter-max)
        # and carry exactly 0.0 latency in the scan too, so initializing
        # delivery = t_inj / lat = 0 reproduces the scan's outputs bitwise
        net, delivery, lat, _ = lax.while_loop(
            lambda st: st[3] < nv, body,
            (net, t_inj_s, jnp.zeros_like(t_inj_s), jnp.int32(0)))
        return net, delivery, lat

    def _lane(net, p, ready, lat_sum, lat_max, mx, extra):
        """Message phase of one step for ONE policy lane.  ``extra`` is the
        lane-invariant per-step operand of the chosen lowering: the
        conflict matrix (fallback chain mode), the live count (prefix
        mode), or None."""
        src, dst, nbytes, links, dirs, nhops, valid = mx
        t_inj = ready[src]
        if needs_sort:
            # stable sort, padding keyed to +inf: the valid prefix orders
            # exactly like the reference engine's host np.argsort
            order = jnp.argsort(jnp.where(valid, t_inj, jnp.inf),
                                stable=True)
            dst_s = dst[order]
            valid_s = valid[order]
            msgs = (links[order], dirs[order], nhops[order], t_inj[order],
                    nbytes[order], valid_s)
        else:
            # <=1 valid message per step: valid slots are a prefix and the
            # stable sort is the identity, so skip it (plan-time flag)
            order = None
            dst_s, valid_s = dst, valid
            msgs = (links, dirs, nhops, t_inj, nbytes, valid)

        if chained:
            net, delivery, lat = _wave_chain(net, p, msgs, valid_s)
            out = None
        elif mode == "chain":
            net, delivery, lat = _wave_phase(net, p, msgs, valid_s, extra,
                                             order)
            out = None
        elif mode == "prefix":
            net, delivery, lat = _prefix_phase(net, p, msgs, valid_s,
                                               extra)
            out = None
        else:
            def msg_step(net, m):
                net, (d, lat, ev) = S._message_step(net, m, proto, pm,
                                                    n_links, params=p)
                return net, ((d, lat, ev) if collect_events else (d, lat))

            net, out = lax.scan(msg_step, net, msgs)
            delivery, lat = out[0], out[1]
        ready = ready.at[dst_s].max(jnp.where(valid_s, delivery, -jnp.inf))
        lat_sum = lat_sum + lat.sum()
        lat_max = jnp.maximum(lat_max, lat.max())
        if collect_events:
            return net, ready, lat_sum, lat_max, out[2]
        return net, ready, lat_sum, lat_max

    def run(nets, params, ready, lat_sum, lat_max, part_mask, xs):
        B = ready.shape[0]

        def step(carry, x):
            nets, ready, lat_sum, lat_max = carry
            ready = ready + x["delta"][None]
            ev = None
            if cap:
                mx = (x["src"], x["dst"], x["nbytes"], x["links"],
                      x["dirs"], x["nhops"], x["valid"])

                def do(ops):
                    nets, ready, ls, lm = ops
                    extra = None
                    if mode == "chain" and not chained and needs_sort:
                        extra = _conflicts(x["links"], x["nhops"],
                                           x["valid"])
                    elif mode == "prefix":
                        extra = x["live"]
                    return jax.vmap(_lane,
                                    in_axes=(0, 0, 0, 0, 0, None, None))(
                        nets, params, ready, ls, lm, mx, extra)

                def skip(ops):
                    if not collect_events:
                        return ops
                    H = x["links"].shape[-1]
                    return ops + ((
                        jnp.full((B, cap, H), n_links, jnp.int32),
                        jnp.zeros((B, cap, H), jnp.float64),
                        jnp.zeros((B, cap, H), jnp.float64),
                        jnp.zeros((B, cap, H), bool)),)

                out = lax.cond(x["has_msgs"], do, skip,
                               (nets, ready, lat_sum, lat_max))
                if collect_events:
                    nets, ready, lat_sum, lat_max, ev = out
                else:
                    nets, ready, lat_sum, lat_max = out
            rmax = jnp.max(jnp.where(part_mask, ready, -jnp.inf), axis=-1)
            ready = jnp.where(x["barrier"] & part_mask, rmax[:, None], ready)
            return (nets, ready, lat_sum, lat_max), ev

        return lax.scan(step, (nets, ready, lat_sum, lat_max), xs)

    return run


@lru_cache(maxsize=None)
def _segment_runner(proto: Policy, pm: PowerModel, n_links: int, cap: int,
                    collect_events: bool, mode: str = "scan",
                    needs_sort: bool = True):
    """One jitted scan over a segment's steps; retraces per (S, B) shape."""
    return partial(jax.jit, donate_argnums=(0, 2, 3, 4))(
        _make_run(proto, pm, n_links, cap, collect_events, mode,
                  needs_sort))


@lru_cache(maxsize=None)
def _multi_segment_runner(proto: Policy, pm: PowerModel, n_links: int,
                          cap: int, mode: str = "scan",
                          needs_sort: bool = True):
    """The multi-trace runner: the per-trace program vmapped over a leading
    T axis.  ``params`` is shared across traces (in_axes None) — every
    trace lane replays the same stacked policy group — while the carry,
    participant mask and segment arrays are per-trace.  Retraces per
    (T, S, B) shape; programs are shared across stack groups with equal
    segment shapes."""
    run = _make_run(proto, pm, n_links, cap, collect_events=False,
                    mode=mode, needs_sort=needs_sort)
    return partial(jax.jit, donate_argnums=(0, 2, 3, 4))(
        jax.vmap(run, in_axes=(0, None, 0, 0, 0, 0, 0)))


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


@jax.jit
def _participant_max(mask, ready):
    """Per-lane makespan: max ``ready`` over participants.  Jitted so the
    -inf fill is a compile-time constant (keeps warm replays transfer-free)."""
    return jnp.max(jnp.where(mask, ready, -jnp.inf), axis=-1)


def init_lanes(pols, plan):
    """Lane setup (the only host->device traffic of a replay): canonical
    proto, stacked params, and the initial scan carry — batched net state,
    zeroed per-node ``ready`` clocks, zeroed latency accumulators."""
    proto = canonical_proto(pols[0])
    params = stack_params(pols)
    nets = jax.vmap(
        lambda p: S.init_net(plan.n_links, proto, params=p))(params)
    B = next(iter(params.values())).shape[0]
    carry = (nets, jnp.zeros((B, plan.n_nodes), jnp.float64),
             jnp.zeros((B,), jnp.float64), jnp.zeros((B,), jnp.float64))
    return proto, params, carry


def run_segments(plan, proto, params, pm, carry, collect_events=False):
    """Execute every plan segment, carrying all state on device.

    ``carry`` is ``init_lanes``'s (nets, ready, lat_sum, lat_max).  Host
    work per segment is ONE jitted-call dispatch — no transfers, no sorts,
    no padding (pinned by tests/test_plan.py under a transfer guard).
    Returns device values ``(nets, t_end (B,), lat_sum (B,), lat_max (B,),
    seg_events)``.
    """
    seg_events = [] if collect_events else None
    for seg in plan.segments:
        md, ns = _seg_flags(seg, proto, collect_events)
        run = _segment_runner(proto, pm, plan.n_links, seg.cap,
                              collect_events, md, ns)
        carry, evs = run(carry[0], params, carry[1], carry[2], carry[3],
                         plan.part_mask, seg.xs)
        if collect_events and seg.cap:
            seg_events.append((seg, evs))
    nets, ready, lat_sum, lat_max = carry
    if plan.has_participants:
        t_end = _participant_max(plan.part_mask, ready)
    else:
        t_end = lat_sum * 0.0
    return nets, t_end, lat_sum, lat_max, seg_events


def replay_plan(plan, pols, pm, collect_events=False):
    """One-stop compiled replay: init lanes, run segments, read back.

    Returns ``(nets, t_end, lat_sum, lat_max, seg_events)`` with the
    scalar accumulators as host numpy (B,) arrays.
    """
    proto, params, carry = init_lanes(pols, plan)
    nets, t_end, lat_sum, lat_max, seg_events = run_segments(
        plan, proto, params, pm, carry, collect_events)
    return (nets, np.asarray(t_end), np.asarray(lat_sum),
            np.asarray(lat_max), seg_events)


# ---------------------------------------------------------------------------
# Multi-trace driver: a (traces x policies) grid in one program per segment
# ---------------------------------------------------------------------------


@jax.jit
def _participant_max_multi(mask, ready):
    """Per-(trace, lane) makespan: max ``ready`` over each trace's own
    participants.  mask (T, n_nodes), ready (T, B, n_nodes) -> (T, B)."""
    return jnp.max(jnp.where(mask[:, None, :], ready, -jnp.inf), axis=-1)


@lru_cache(maxsize=None)
def _multi_init(proto: Policy, n_links: int, n_nodes: int, T: int):
    """Jitted (T, B) carry constructor — ONE program per (proto, T, B)
    instead of a spray of eager broadcast/zeros ops, keeping the grid
    path's compile count bounded by its segment programs."""
    @jax.jit
    def init(params):
        nets1 = jax.vmap(
            lambda p: S.init_net(n_links, proto, params=p))(params)
        B = next(iter(params.values())).shape[0]
        nets = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (T,) + x.shape), nets1)
        return (nets, jnp.zeros((T, B, n_nodes), jnp.float64),
                jnp.zeros((T, B), jnp.float64), jnp.zeros((T, B), jnp.float64))
    return init


def init_lanes_multi(pols, batch):
    """Lane setup for a :class:`~repro.traffic.plan.PlanBatch`: the B-lane
    initial state of ``init_lanes`` replicated along a leading T trace axis
    (initial net state depends only on the policy, so every trace lane
    starts from the same bits as its single-trace replay)."""
    proto = canonical_proto(pols[0])
    params = stack_params(pols)
    carry = _multi_init(proto, batch.n_links, batch.n_nodes,
                        batch.n_traces)(params)
    return proto, params, carry


def run_segments_multi(batch, proto, params, pm, carry):
    """Execute every segment of a :class:`PlanBatch`, carrying the whole
    (T, B, ...) grid state on device.  Host work per segment is one
    jitted-call dispatch, exactly like the single-trace path.  Returns
    device ``(nets, t_end (T, B), lat_sum (T, B), lat_max (T, B))``."""
    for seg in batch.segments:
        md, ns = _seg_flags(seg, proto)
        run = _multi_segment_runner(proto, pm, batch.n_links, seg.cap,
                                    md, ns)
        carry, _ = run(carry[0], params, carry[1], carry[2], carry[3],
                       batch.part_mask, seg.xs)
    nets, ready, lat_sum, lat_max = carry
    t_end = _participant_max_multi(batch.part_mask, ready)
    return nets, t_end, lat_sum, lat_max


def replay_plans(batch, pols, pm):
    """Compiled (traces x policies) grid replay over a ``PlanBatch``.

    Returns ``(nets, t_end, lat_sum, lat_max)`` where the net state keeps
    its (T, B, ...) leading axes on device and the scalar accumulators come
    back as host numpy (T, B) arrays.  Per-(t, b) cell results are
    bit-identical to that trace's own single-trace ``replay_plan`` —
    the multi runner is the same program vmapped over T.
    """
    proto, params, carry = init_lanes_multi(pols, batch)
    nets, t_end, lat_sum, lat_max = run_segments_multi(
        batch, proto, params, pm, carry)
    t_end = np.asarray(t_end)
    # traces with no participants have an all-False mask row (-inf max)
    t_end = np.where(batch.has_participants[:, None], t_end, 0.0)
    return nets, t_end, np.asarray(lat_sum), np.asarray(lat_max)


def events_to_host(plan, seg_events):
    """Lower collected events to the classic per-message-step host list
    ``[(link, t_start, t_end), ...]`` (active hops only, replay order).

    Only the B=1 (serial) path collects events; lane 0 is extracted.
    """
    out = []
    for seg, evs in seg_events:
        lp, ts, te, act = (np.asarray(x) for x in evs)   # (S, B, cap, H)
        for i in range(seg.n_steps):
            if not seg.host_has_msgs[i]:
                continue
            m = act[i, 0]
            out.append((lp[i, 0][m], ts[i, 0][m], te[i, 0][m]))
    return out
