"""Compiled trace replay: execute a TracePlan as ``lax.scan`` over steps.

Stage 2 of the plan/execute split (DESIGN.md §2).  The executor carries
(``net`` EEE/predictor state, per-node ``ready`` clocks, latency
accumulators) entirely on device across the whole trace:

  * injection-time ordering runs as a **stable ``jnp.argsort`` inside the
    scanned step** (per batch lane — each policy's latency feedback gives
    it a different replay order), replacing the per-step host sorts;
  * delivery maxima update ``ready`` via **scatter-max** (invalid slots
    carry -inf, so padding never races);
  * compute advances and barriers are **scan-step branches**: a dense
    per-step clock delta plus a masked participant-max select;
  * message-less steps skip the message machinery through a ``lax.cond``
    on the plan's per-step ``has_msgs`` flag.

The serial engine is the B=1 case of the batched one: ``policies`` lanes
share a canonical static proto (``eee.canonical_proto``) and read their
numerics lane-wise from a stacked parameter vector, so one compiled
program serves every policy of a static group — and every B — per segment
shape.  Between segments only jitted-call dispatch happens on host; the
carry never leaves the device (``tests/test_plan.py`` pins this with a
``jax.transfer_guard``).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import simulator as S
from repro.core.eee import (PARAM_FIELDS, Policy, PowerModel,
                            canonical_proto, policy_params)


def stack_params(pols) -> dict:
    """Stack each policy's numeric parameter vector into (B,) f64 arrays."""
    cols = [policy_params(p) for p in pols]
    return {f: jnp.asarray([c[f] for c in cols], jnp.float64)
            for f in PARAM_FIELDS}


# ---------------------------------------------------------------------------
# Compiled per-segment runner
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _segment_runner(proto: Policy, pm: PowerModel, n_links: int, cap: int,
                    collect_events: bool):
    """One jitted scan over a segment's steps; retraces per (S, B) shape."""

    def _lane(net, p, ready, lat_sum, lat_max, mx):
        """Message phase of one step for ONE policy lane."""
        src, dst, nbytes, links, dirs, nhops, valid = mx
        t_inj = ready[src]
        # stable sort, padding keyed to +inf: the valid prefix orders
        # exactly like the reference engine's host np.argsort
        order = jnp.argsort(jnp.where(valid, t_inj, jnp.inf), stable=True)
        dst_s = dst[order]
        valid_s = valid[order]
        msgs = (links[order], dirs[order], nhops[order], t_inj[order],
                nbytes[order], valid_s)

        def msg_step(net, m):
            net, (d, lat, ev) = S._message_step(net, m, proto, pm, n_links,
                                                params=p)
            return net, ((d, lat, ev) if collect_events else (d, lat))

        net, out = lax.scan(msg_step, net, msgs)
        delivery, lat = out[0], out[1]
        ready = ready.at[dst_s].max(jnp.where(valid_s, delivery, -jnp.inf))
        lat_sum = lat_sum + lat.sum()
        lat_max = jnp.maximum(lat_max, lat.max())
        if collect_events:
            return net, ready, lat_sum, lat_max, out[2]
        return net, ready, lat_sum, lat_max

    @partial(jax.jit, donate_argnums=(0, 2, 3, 4))
    def run(nets, params, ready, lat_sum, lat_max, part_mask, xs):
        B = ready.shape[0]

        def step(carry, x):
            nets, ready, lat_sum, lat_max = carry
            ready = ready + x["delta"][None]
            ev = None
            if cap:
                mx = (x["src"], x["dst"], x["nbytes"], x["links"],
                      x["dirs"], x["nhops"], x["valid"])

                def do(ops):
                    nets, ready, ls, lm = ops
                    return jax.vmap(_lane, in_axes=(0, 0, 0, 0, 0, None))(
                        nets, params, ready, ls, lm, mx)

                def skip(ops):
                    if not collect_events:
                        return ops
                    H = x["links"].shape[-1]
                    return ops + ((
                        jnp.full((B, cap, H), n_links, jnp.int32),
                        jnp.zeros((B, cap, H), jnp.float64),
                        jnp.zeros((B, cap, H), jnp.float64),
                        jnp.zeros((B, cap, H), bool)),)

                out = lax.cond(x["has_msgs"], do, skip,
                               (nets, ready, lat_sum, lat_max))
                if collect_events:
                    nets, ready, lat_sum, lat_max, ev = out
                else:
                    nets, ready, lat_sum, lat_max = out
            rmax = jnp.max(jnp.where(part_mask, ready, -jnp.inf), axis=-1)
            ready = jnp.where(x["barrier"] & part_mask, rmax[:, None], ready)
            return (nets, ready, lat_sum, lat_max), ev

        return lax.scan(step, (nets, ready, lat_sum, lat_max), xs)

    return run


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


@jax.jit
def _participant_max(mask, ready):
    """Per-lane makespan: max ``ready`` over participants.  Jitted so the
    -inf fill is a compile-time constant (keeps warm replays transfer-free)."""
    return jnp.max(jnp.where(mask, ready, -jnp.inf), axis=-1)


def init_lanes(pols, plan):
    """Lane setup (the only host->device traffic of a replay): canonical
    proto, stacked params, and the initial scan carry — batched net state,
    zeroed per-node ``ready`` clocks, zeroed latency accumulators."""
    proto = canonical_proto(pols[0])
    params = stack_params(pols)
    nets = jax.vmap(
        lambda p: S.init_net(plan.n_links, proto, params=p))(params)
    B = next(iter(params.values())).shape[0]
    carry = (nets, jnp.zeros((B, plan.n_nodes), jnp.float64),
             jnp.zeros((B,), jnp.float64), jnp.zeros((B,), jnp.float64))
    return proto, params, carry


def run_segments(plan, proto, params, pm, carry, collect_events=False):
    """Execute every plan segment, carrying all state on device.

    ``carry`` is ``init_lanes``'s (nets, ready, lat_sum, lat_max).  Host
    work per segment is ONE jitted-call dispatch — no transfers, no sorts,
    no padding (pinned by tests/test_plan.py under a transfer guard).
    Returns device values ``(nets, t_end (B,), lat_sum (B,), lat_max (B,),
    seg_events)``.
    """
    seg_events = [] if collect_events else None
    for seg in plan.segments:
        run = _segment_runner(proto, pm, plan.n_links, seg.cap,
                              collect_events)
        carry, evs = run(carry[0], params, carry[1], carry[2], carry[3],
                         plan.part_mask, seg.xs)
        if collect_events and seg.cap:
            seg_events.append((seg, evs))
    nets, ready, lat_sum, lat_max = carry
    if plan.has_participants:
        t_end = _participant_max(plan.part_mask, ready)
    else:
        t_end = lat_sum * 0.0
    return nets, t_end, lat_sum, lat_max, seg_events


def replay_plan(plan, pols, pm, collect_events=False):
    """One-stop compiled replay: init lanes, run segments, read back.

    Returns ``(nets, t_end, lat_sum, lat_max, seg_events)`` with the
    scalar accumulators as host numpy (B,) arrays.
    """
    proto, params, carry = init_lanes(pols, plan)
    nets, t_end, lat_sum, lat_max, seg_events = run_segments(
        plan, proto, params, pm, carry, collect_events)
    return (nets, np.asarray(t_end), np.asarray(lat_sum),
            np.asarray(lat_max), seg_events)


def events_to_host(plan, seg_events):
    """Lower collected events to the classic per-message-step host list
    ``[(link, t_start, t_end), ...]`` (active hops only, replay order).

    Only the B=1 (serial) path collects events; lane 0 is extracted.
    """
    out = []
    for seg, evs in seg_events:
        lp, ts, te, act = (np.asarray(x) for x in evs)   # (S, B, cap, H)
        for i in range(seg.n_steps):
            if not seg.host_has_msgs[i]:
                continue
            m = act[i, 0]
            out.append((lp[i, 0][m], ts[i, 0][m], te[i, 0][m]))
    return out
