"""Compiled trace replay: execute a TracePlan as ``lax.scan`` over steps.

Stage 2 of the plan/execute split (DESIGN.md §2).  The executor carries
(``net`` EEE/predictor state, per-node ``ready`` clocks, latency
accumulators) entirely on device across the whole trace:

  * injection-time ordering runs as a **stable ``jnp.argsort`` inside the
    scanned step** (per batch lane — each policy's latency feedback gives
    it a different replay order), replacing the per-step host sorts;
  * delivery maxima update ``ready`` via **scatter-max** (invalid slots
    carry -inf, so padding never races);
  * compute advances and barriers are **scan-step branches**: a dense
    per-step clock delta plus a masked participant-max select;
  * message-less steps skip the message machinery through a ``lax.cond``
    on the plan's per-step ``has_msgs`` flag.

The serial engine is the B=1 case of the batched one: ``policies`` lanes
share a canonical static proto (``eee.canonical_proto``) and read their
numerics lane-wise from a stacked parameter vector, so one compiled
program serves every policy of a static group — and every B — per segment
shape.  The ``net`` carry is an opaque pytree to this layer: the FSM
fields the dual-mode kinds add (``deadline2``/``time_sleep2``/``n_deep``,
plus the coalescing-cycle state of the ``coalesce`` kind — DESIGN.md §6)
vmap over the B policy axis and the T trace axis like every other entry,
with no executor changes.  Between segments only jitted-call dispatch happens on host; the
carry never leaves the device (``tests/test_plan.py`` pins this with a
``jax.transfer_guard``).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import simulator as S
from repro.core.eee import (PARAM_FIELDS, Policy, PowerModel,
                            canonical_proto, policy_params)


def stack_params(pols) -> dict:
    """Stack each policy's numeric parameter vector into (B,) f64 arrays."""
    cols = [policy_params(p) for p in pols]
    return {f: jnp.asarray([c[f] for c in cols], jnp.float64)
            for f in PARAM_FIELDS}


# ---------------------------------------------------------------------------
# Compiled per-segment runner
# ---------------------------------------------------------------------------


def _make_run(proto: Policy, pm: PowerModel, n_links: int, cap: int,
              collect_events: bool):
    """Build the (un-jitted) per-trace segment program: one ``lax.scan``
    over a segment's steps with B policy lanes vmapped inside the step.

    ``_segment_runner`` jits it directly (the single-trace path);
    ``_multi_segment_runner`` vmaps it once more over a leading trace axis
    (the ``PlanBatch`` path) — same step arithmetic, so per-lane results
    are bit-identical between the two."""

    def _lane(net, p, ready, lat_sum, lat_max, mx):
        """Message phase of one step for ONE policy lane."""
        src, dst, nbytes, links, dirs, nhops, valid = mx
        t_inj = ready[src]
        # stable sort, padding keyed to +inf: the valid prefix orders
        # exactly like the reference engine's host np.argsort
        order = jnp.argsort(jnp.where(valid, t_inj, jnp.inf), stable=True)
        dst_s = dst[order]
        valid_s = valid[order]
        msgs = (links[order], dirs[order], nhops[order], t_inj[order],
                nbytes[order], valid_s)

        def msg_step(net, m):
            net, (d, lat, ev) = S._message_step(net, m, proto, pm, n_links,
                                                params=p)
            return net, ((d, lat, ev) if collect_events else (d, lat))

        net, out = lax.scan(msg_step, net, msgs)
        delivery, lat = out[0], out[1]
        ready = ready.at[dst_s].max(jnp.where(valid_s, delivery, -jnp.inf))
        lat_sum = lat_sum + lat.sum()
        lat_max = jnp.maximum(lat_max, lat.max())
        if collect_events:
            return net, ready, lat_sum, lat_max, out[2]
        return net, ready, lat_sum, lat_max

    def run(nets, params, ready, lat_sum, lat_max, part_mask, xs):
        B = ready.shape[0]

        def step(carry, x):
            nets, ready, lat_sum, lat_max = carry
            ready = ready + x["delta"][None]
            ev = None
            if cap:
                mx = (x["src"], x["dst"], x["nbytes"], x["links"],
                      x["dirs"], x["nhops"], x["valid"])

                def do(ops):
                    nets, ready, ls, lm = ops
                    return jax.vmap(_lane, in_axes=(0, 0, 0, 0, 0, None))(
                        nets, params, ready, ls, lm, mx)

                def skip(ops):
                    if not collect_events:
                        return ops
                    H = x["links"].shape[-1]
                    return ops + ((
                        jnp.full((B, cap, H), n_links, jnp.int32),
                        jnp.zeros((B, cap, H), jnp.float64),
                        jnp.zeros((B, cap, H), jnp.float64),
                        jnp.zeros((B, cap, H), bool)),)

                out = lax.cond(x["has_msgs"], do, skip,
                               (nets, ready, lat_sum, lat_max))
                if collect_events:
                    nets, ready, lat_sum, lat_max, ev = out
                else:
                    nets, ready, lat_sum, lat_max = out
            rmax = jnp.max(jnp.where(part_mask, ready, -jnp.inf), axis=-1)
            ready = jnp.where(x["barrier"] & part_mask, rmax[:, None], ready)
            return (nets, ready, lat_sum, lat_max), ev

        return lax.scan(step, (nets, ready, lat_sum, lat_max), xs)

    return run


@lru_cache(maxsize=None)
def _segment_runner(proto: Policy, pm: PowerModel, n_links: int, cap: int,
                    collect_events: bool):
    """One jitted scan over a segment's steps; retraces per (S, B) shape."""
    return partial(jax.jit, donate_argnums=(0, 2, 3, 4))(
        _make_run(proto, pm, n_links, cap, collect_events))


@lru_cache(maxsize=None)
def _multi_segment_runner(proto: Policy, pm: PowerModel, n_links: int,
                          cap: int):
    """The multi-trace runner: the per-trace program vmapped over a leading
    T axis.  ``params`` is shared across traces (in_axes None) — every
    trace lane replays the same stacked policy group — while the carry,
    participant mask and segment arrays are per-trace.  Retraces per
    (T, S, B) shape; programs are shared across stack groups with equal
    segment shapes."""
    run = _make_run(proto, pm, n_links, cap, collect_events=False)
    return partial(jax.jit, donate_argnums=(0, 2, 3, 4))(
        jax.vmap(run, in_axes=(0, None, 0, 0, 0, 0, 0)))


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


@jax.jit
def _participant_max(mask, ready):
    """Per-lane makespan: max ``ready`` over participants.  Jitted so the
    -inf fill is a compile-time constant (keeps warm replays transfer-free)."""
    return jnp.max(jnp.where(mask, ready, -jnp.inf), axis=-1)


def init_lanes(pols, plan):
    """Lane setup (the only host->device traffic of a replay): canonical
    proto, stacked params, and the initial scan carry — batched net state,
    zeroed per-node ``ready`` clocks, zeroed latency accumulators."""
    proto = canonical_proto(pols[0])
    params = stack_params(pols)
    nets = jax.vmap(
        lambda p: S.init_net(plan.n_links, proto, params=p))(params)
    B = next(iter(params.values())).shape[0]
    carry = (nets, jnp.zeros((B, plan.n_nodes), jnp.float64),
             jnp.zeros((B,), jnp.float64), jnp.zeros((B,), jnp.float64))
    return proto, params, carry


def run_segments(plan, proto, params, pm, carry, collect_events=False):
    """Execute every plan segment, carrying all state on device.

    ``carry`` is ``init_lanes``'s (nets, ready, lat_sum, lat_max).  Host
    work per segment is ONE jitted-call dispatch — no transfers, no sorts,
    no padding (pinned by tests/test_plan.py under a transfer guard).
    Returns device values ``(nets, t_end (B,), lat_sum (B,), lat_max (B,),
    seg_events)``.
    """
    seg_events = [] if collect_events else None
    for seg in plan.segments:
        run = _segment_runner(proto, pm, plan.n_links, seg.cap,
                              collect_events)
        carry, evs = run(carry[0], params, carry[1], carry[2], carry[3],
                         plan.part_mask, seg.xs)
        if collect_events and seg.cap:
            seg_events.append((seg, evs))
    nets, ready, lat_sum, lat_max = carry
    if plan.has_participants:
        t_end = _participant_max(plan.part_mask, ready)
    else:
        t_end = lat_sum * 0.0
    return nets, t_end, lat_sum, lat_max, seg_events


def replay_plan(plan, pols, pm, collect_events=False):
    """One-stop compiled replay: init lanes, run segments, read back.

    Returns ``(nets, t_end, lat_sum, lat_max, seg_events)`` with the
    scalar accumulators as host numpy (B,) arrays.
    """
    proto, params, carry = init_lanes(pols, plan)
    nets, t_end, lat_sum, lat_max, seg_events = run_segments(
        plan, proto, params, pm, carry, collect_events)
    return (nets, np.asarray(t_end), np.asarray(lat_sum),
            np.asarray(lat_max), seg_events)


# ---------------------------------------------------------------------------
# Multi-trace driver: a (traces x policies) grid in one program per segment
# ---------------------------------------------------------------------------


@jax.jit
def _participant_max_multi(mask, ready):
    """Per-(trace, lane) makespan: max ``ready`` over each trace's own
    participants.  mask (T, n_nodes), ready (T, B, n_nodes) -> (T, B)."""
    return jnp.max(jnp.where(mask[:, None, :], ready, -jnp.inf), axis=-1)


@lru_cache(maxsize=None)
def _multi_init(proto: Policy, n_links: int, n_nodes: int, T: int):
    """Jitted (T, B) carry constructor — ONE program per (proto, T, B)
    instead of a spray of eager broadcast/zeros ops, keeping the grid
    path's compile count bounded by its segment programs."""
    @jax.jit
    def init(params):
        nets1 = jax.vmap(
            lambda p: S.init_net(n_links, proto, params=p))(params)
        B = next(iter(params.values())).shape[0]
        nets = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (T,) + x.shape), nets1)
        return (nets, jnp.zeros((T, B, n_nodes), jnp.float64),
                jnp.zeros((T, B), jnp.float64), jnp.zeros((T, B), jnp.float64))
    return init


def init_lanes_multi(pols, batch):
    """Lane setup for a :class:`~repro.traffic.plan.PlanBatch`: the B-lane
    initial state of ``init_lanes`` replicated along a leading T trace axis
    (initial net state depends only on the policy, so every trace lane
    starts from the same bits as its single-trace replay)."""
    proto = canonical_proto(pols[0])
    params = stack_params(pols)
    carry = _multi_init(proto, batch.n_links, batch.n_nodes,
                        batch.n_traces)(params)
    return proto, params, carry


def run_segments_multi(batch, proto, params, pm, carry):
    """Execute every segment of a :class:`PlanBatch`, carrying the whole
    (T, B, ...) grid state on device.  Host work per segment is one
    jitted-call dispatch, exactly like the single-trace path.  Returns
    device ``(nets, t_end (T, B), lat_sum (T, B), lat_max (T, B))``."""
    for seg in batch.segments:
        run = _multi_segment_runner(proto, pm, batch.n_links, seg.cap)
        carry, _ = run(carry[0], params, carry[1], carry[2], carry[3],
                       batch.part_mask, seg.xs)
    nets, ready, lat_sum, lat_max = carry
    t_end = _participant_max_multi(batch.part_mask, ready)
    return nets, t_end, lat_sum, lat_max


def replay_plans(batch, pols, pm):
    """Compiled (traces x policies) grid replay over a ``PlanBatch``.

    Returns ``(nets, t_end, lat_sum, lat_max)`` where the net state keeps
    its (T, B, ...) leading axes on device and the scalar accumulators come
    back as host numpy (T, B) arrays.  Per-(t, b) cell results are
    bit-identical to that trace's own single-trace ``replay_plan`` —
    the multi runner is the same program vmapped over T.
    """
    proto, params, carry = init_lanes_multi(pols, batch)
    nets, t_end, lat_sum, lat_max = run_segments_multi(
        batch, proto, params, pm, carry)
    t_end = np.asarray(t_end)
    # traces with no participants have an all-False mask row (-inf max)
    t_end = np.where(batch.has_participants[:, None], t_end, 0.0)
    return nets, t_end, np.asarray(lat_sum), np.asarray(lat_max)


def events_to_host(plan, seg_events):
    """Lower collected events to the classic per-message-step host list
    ``[(link, t_start, t_end), ...]`` (active hops only, replay order).

    Only the B=1 (serial) path collects events; lane 0 is extracted.
    """
    out = []
    for seg, evs in seg_events:
        lp, ts, te, act = (np.asarray(x) for x in evs)   # (S, B, cap, H)
        for i in range(seg.n_steps):
            if not seg.host_has_msgs[i]:
                continue
            m = act[i, 0]
            out.append((lp[i, 0][m], ts[i, 0][m], te[i, 0][m]))
    return out
