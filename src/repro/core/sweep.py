"""Batched multi-policy sweep engine: B policies per trace replay, compiled.

The paper's whole evaluation protocol (§4) is a grid sweep — PerfBound vs
PerfBoundCorrect across degradation bounds x histogram modes x sleep states.
Replaying the trace once per policy recompiles and re-walks identical
traffic for every grid cell.  Here a :class:`~repro.core.eee.Policy` factors
into static structure (``eee.static_key``) and a numeric parameter vector
(``eee.policy_params``); policies sharing static structure are stacked along
a leading batch axis and evaluated side by side:

  * the network state (``simulator.init_net``) gains a leading policy axis
    via ``jax.vmap`` — including the PerfBound predictor state;
  * the trace is compiled ONCE per topology into a device-resident
    :class:`~repro.traffic.plan.TracePlan` (``repro.traffic.plan``) —
    routes, message padding and phase lowering are shared by EVERY group
    of the sweep through the plan cache, instead of being recomputed per
    group;
  * each plan segment runs as a single compiled ``lax.scan`` over steps
    (``repro.core.replay``) whose message phase is the vmapped
    ``simulator._message_step`` reading per-lane parameters; injection
    order is policy-dependent (latency feedback shifts per-node clocks),
    so each lane sorts its own lane's clocks with a stable ``jnp.argsort``
    INSIDE the scanned step — nothing returns to host between steps.

``sweep_policies`` is the public entry point; ``compare_policies`` in
``repro.core.simulator`` is built on top of it.  Sleep states lower to
numbers (t_w/t_s/power_frac — and the dual-mode FSM's second row plus its
``t_dst``/coalescing timers, DESIGN.md §6), so Fast Wake / Deep Sleep /
ladder variants of the same kind batch together: a typical paper grid
(2 kinds x 3 bounds x 2 states) collapses from 12 serial replays into 2
batched ones, and a whole demotion-timer or coalescing-window curve is
ONE batched replay of its kind's static group.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import replay
from repro.core import simulator as S
from repro.core.eee import PowerModel, static_key
from repro.core.replay import stack_params  # noqa: F401 (public re-export)
from repro.traffic.plan import (compile_plan, group_stackable,
                                stack_plans_cached)


# ---------------------------------------------------------------------------
# Grouping
# ---------------------------------------------------------------------------


def group_policies(policies: dict) -> list:
    """Partition {name: Policy} into static-structure groups.

    Returns a list of name-lists; each group compiles to ONE batched program
    (order inside a group and across groups follows insertion order).
    """
    groups: dict = {}
    for name, pol in policies.items():
        groups.setdefault(static_key(pol), []).append(name)
    return list(groups.values())


# ---------------------------------------------------------------------------
# Batched trace replay (plan executor wrapper)
# ---------------------------------------------------------------------------


def _sweep_group(trace, topo, names, pols, pm):
    """Replay ``trace`` once for a static-structure group of B policies."""
    plan = compile_plan(trace, topo)
    nets, t_end, lat_sum, lat_max, _ = replay.replay_plan(plan, pols, pm)
    out = {}
    for b, name in enumerate(names):
        net_b = jax.tree.map(lambda x: x[b], nets)
        out[name] = S.summarize(net_b, float(t_end[b]), plan.busy,
                                float(lat_sum[b]), float(lat_max[b]),
                                plan.n_msgs, pols[b], pm, topo)
    return out


def sweep_policies(trace, topo, policies: dict, pm: PowerModel | None = None,
                   max_group: int | None = None) -> dict:
    """Evaluate every policy in {name: Policy} over one trace, batched.

    Policies are grouped by static structure (``eee.static_key``); each
    group replays the trace ONCE with a leading policy axis of width B and
    a single compiled scan per plan segment.  All groups share one cached
    TracePlan, so routes and padding are computed once per (trace, topo) —
    not once per group.  Returns {name: SimResult} in the caller's
    insertion order — results match serial ``simulator.simulate_trace``
    (and the step-loop reference engine) per policy to float64 tolerance.

    ``max_group`` caps the batch width (splits big groups), bounding device
    memory at paper scale: predictor state is O(B * n_links * hist_bins).
    """
    pm = pm or PowerModel()
    out = {}
    for names in group_policies(policies):
        cap = max_group or len(names)
        for i in range(0, len(names), cap):
            chunk = names[i:i + cap]
            out.update(_sweep_group(trace, topo, chunk,
                                    [policies[n] for n in chunk], pm))
    return {name: out[name] for name in policies}


# ---------------------------------------------------------------------------
# (scenarios x policies) grid: multi-trace batched replay
# ---------------------------------------------------------------------------


def sweep_cells(traces: dict, topo, cells: dict,
                pm: PowerModel | None = None,
                max_group: int | None = None,
                packing: str = "pow2") -> dict:
    """Evaluate a RAGGED (trace x policy) grid, batched along both axes.

    ``cells`` maps each trace name to its own {policy_name: Policy} dict —
    the general case of :func:`sweep_scenarios`, where different traces may
    request different policy subsets (the auto-tuner's refinement rounds
    keep only the surviving (scenario, static-group) cells).  Policies
    sharing a name across traces must be equal — a name is one grid column.

    Batching stays maximal despite the raggedness: traces stack by compiled
    plan shape exactly as in ``sweep_scenarios``, and within a stack each
    static policy group replays the UNION of the stack's requested lanes in
    one vmapped program per segment shape (the B policy axis is shared by
    every trace lane of a program, so evaluating a superset costs vmap
    lanes, not programs).  Only the requested cells are summarized and
    returned: ``{trace_name: {policy_name: SimResult}}`` in the callers'
    insertion orders, every cell bit-identical to that trace's own serial
    ``simulator.simulate_trace``.

    ``max_group`` caps the policy-batch width exactly as in
    ``sweep_policies``; device memory scales with T x B lanes.

    ``packing`` selects the stacked plans' segment layout: ``"pow2"``
    (the production default) or ``"ragged"`` (size-class caps + merged
    tails via ``plan.repack_plans`` — less padding memory and inner-scan
    work, bit-identical results).  Stacked batches come from the
    ``stack_plans_cached`` LRU either way, so warm sweeps reuse resident
    device arrays.

    When a device mesh is active (``repro.distributed.shard_sweep`` —
    ``use_mesh``/``set_mesh``, or auto mode with >1 visible device), each
    (T, B) replay dispatches onto the mesh with the plan arrays sharded
    along the trace axis; results stay bit-identical.
    """
    from repro.distributed import shard_sweep
    pm = pm or PowerModel()
    tnames = list(cells)
    for tn in tnames:
        for pn, pol in cells[tn].items():
            first = next(c[pn] for c in cells.values() if pn in c)
            assert pol == first, \
                f"policy {pn!r} differs across traces (one name, one column)"
    plans = [compile_plan(traces[n], topo) for n in tnames]
    out: dict = {n: {} for n in tnames}
    for idx in group_stackable(plans):
        batch = stack_plans_cached([plans[i] for i in idx],
                                   [tnames[i] for i in idx],
                                   packing=packing)
        union: dict = {}
        for gi in idx:
            union.update(cells[tnames[gi]])
        for pnames in group_policies(union):
            cap = max_group or len(pnames)
            for i in range(0, len(pnames), cap):
                chunk = pnames[i:i + cap]
                pols = [union[n] for n in chunk]
                mesh = shard_sweep.active_mesh(batch.n_traces, len(chunk))
                if mesh is not None:
                    nets, t_end, lat_sum, lat_max = \
                        shard_sweep.replay_plans_sharded(
                            batch, pols, pm, mesh)
                else:
                    nets, t_end, lat_sum, lat_max = replay.replay_plans(
                        batch, pols, pm)
                # one readback for the whole (T, B) grid: per-cell host
                # numpy views, not one tiny sliced device program per cell
                nets = jax.tree.map(np.asarray, nets)
                for ti, gi in enumerate(idx):
                    want = cells[tnames[gi]]
                    for b, pname in enumerate(chunk):
                        if pname not in want:
                            continue
                        net_tb = jax.tree.map(lambda x: x[ti, b], nets)
                        out[tnames[gi]][pname] = S.summarize(
                            net_tb, float(t_end[ti, b]),
                            float(batch.busy[ti]),
                            float(lat_sum[ti, b]), float(lat_max[ti, b]),
                            int(batch.n_msgs[ti]), pols[b], pm, topo)
    return {tn: {pn: out[tn][pn] for pn in cells[tn]} for tn in cells}


def sweep_scenarios(traces: dict, topo, policies: dict,
                    pm: PowerModel | None = None,
                    max_group: int | None = None,
                    packing: str = "pow2") -> dict:
    """Evaluate a full (traces x policies) grid, batched along BOTH axes.

    ``traces`` is {name: Trace}.  Each trace compiles (or fetches) its
    cached :class:`~repro.traffic.plan.TracePlan`; plans sharing a compiled
    shape (``plan.plan_shape_key``) stack along a leading trace axis
    (``plan.stack_plans``), and each static policy group replays the whole
    stack in one vmapped program per segment shape
    (``replay.replay_plans``).  Compile count is therefore bounded by
    distinct (segment shape, T, B) triples — not by traces x policy-groups:
    stack groups with equal segment shapes share programs, and singleton
    stacks (T=1) still reuse any equal-shape program.

    Returns ``{trace_name: {policy_name: SimResult}}`` in the callers'
    insertion orders; every cell is bit-identical to that trace's own
    serial ``simulator.simulate_trace`` under the same policy.  The
    rectangular case of :func:`sweep_cells`.

    ``max_group`` caps the policy-batch width exactly as in
    ``sweep_policies``; device memory scales with T x B lanes.
    """
    return sweep_cells(traces, topo, {tn: policies for tn in traces},
                       pm, max_group=max_group, packing=packing)
