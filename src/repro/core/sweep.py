"""Batched multi-policy sweep engine: B policies per trace chunk in ONE scan.

The paper's whole evaluation protocol (§4) is a grid sweep — PerfBound vs
PerfBoundCorrect across degradation bounds x histogram modes x sleep states.
Replaying the trace once per policy recompiles and re-walks identical
traffic for every grid cell.  Here a :class:`~repro.core.eee.Policy` factors
into static structure (``eee.static_key``) and a numeric parameter vector
(``eee.policy_params``); policies sharing static structure are stacked along
a leading batch axis and evaluated side by side:

  * the network state (``simulator.init_net``) gains a leading policy axis
    via ``jax.vmap`` — including the PerfBound predictor state;
  * each trace chunk runs as a single compiled ``lax.scan`` whose step is
    the vmapped ``simulator._message_step`` reading per-lane parameters;
  * message injection order is policy-dependent (latency feedback shifts
    per-node clocks), so each lane carries its own host-side sort of the
    chunk — the device pass stays shared.

``sweep_policies`` is the public entry point; ``compare_policies`` in
``repro.core.simulator`` is built on top of it.  Sleep states lower to
numbers (t_w/t_s/power_frac), so Fast Wake and Deep Sleep variants of the
same predictor batch together; a typical paper grid (2 kinds x 3 bounds x
2 states) collapses from 12 serial replays into 2 batched ones.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import simulator as S
from repro.core.eee import (PARAM_FIELDS, Policy, PowerModel, policy_params,
                            static_key)


# ---------------------------------------------------------------------------
# Grouping + parameter stacking
# ---------------------------------------------------------------------------


def group_policies(policies: dict) -> list:
    """Partition {name: Policy} into static-structure groups.

    Returns a list of name-lists; each group compiles to ONE batched program
    (order inside a group and across groups follows insertion order).
    """
    groups: dict = {}
    for name, pol in policies.items():
        groups.setdefault(static_key(pol), []).append(name)
    return list(groups.values())


def stack_params(pols: list) -> dict:
    """Stack each policy's numeric parameter vector into (B,) f64 arrays."""
    cols = [policy_params(p) for p in pols]
    return {f: jnp.asarray([c[f] for c in cols], jnp.float64)
            for f in PARAM_FIELDS}


# ---------------------------------------------------------------------------
# Compiled batched chunk
# ---------------------------------------------------------------------------


def _canonical_proto(policy: Policy) -> Policy:
    """Reset every numeric field to a fixed value, keeping only static
    structure (plus the ``hist_decay < 1`` program flag).  Protos from the
    same static group then hash equal, so ``max_group`` chunk splits and
    sibling groups reuse one compiled program instead of recompiling per
    chunk prototype."""
    return dataclasses.replace(
        policy, sleep_state="deep_sleep", t_pdt=0.0, bound=0.01,
        tpdt_init=10e-3, max_tpdt=10e-3, sync_overhead=5e-9,
        hist_bin_width=10e-6, hist_log_min=1e-7, hist_log_max=10.0,
        hist_clear_n=250,
        hist_decay=0.5 if policy.hist_decay < 1.0 else 1.0)


@lru_cache(maxsize=None)
def _compiled_sweep_chunk(proto: Policy, pm: PowerModel, n_links: int):
    """One jitted scan evaluating all B lanes of a policy group per chunk.

    ``proto`` must be canonical (``_canonical_proto``): it supplies only
    static structure; every numeric value the compiled code reads comes
    lane-wise from ``params``.
    """
    def lane(net, p, m):
        net, (d, lat, _ev) = S._message_step(net, m, proto, pm, n_links,
                                             params=p)
        return net, (d, lat)

    @partial(jax.jit, donate_argnums=(0,))
    def run(nets, params, msgs):
        def step(nets, m):
            return jax.vmap(lane, in_axes=(0, 0, 0))(nets, params, m)
        return lax.scan(step, nets, msgs)

    return run


def _pad_msgs_batch(links, dirs, nhops, t_inj, nbytes, bucket_min=64):
    """Per-lane-ordered message arrays (B, M, ...) -> scan-ready tuples
    (cap, B, ...) padded to the same power-of-two buckets as the serial
    ``simulator._pad_msgs`` (keeps recompilation behaviour aligned)."""
    B, M = nhops.shape
    cap = S._bucket_cap(M, bucket_min)
    pad = cap - M

    def p(a, fill=0):
        return np.concatenate(
            [a, np.full((B, pad) + a.shape[2:], fill, a.dtype)], axis=1)

    valid = np.concatenate([np.ones((B, M), bool), np.zeros((B, pad), bool)],
                           axis=1)
    out = (p(links, -1), p(dirs), p(nhops), p(t_inj.astype(np.float64)),
           p(nbytes.astype(np.float64)), valid)
    return tuple(jnp.asarray(np.swapaxes(a, 0, 1)) for a in out)


# ---------------------------------------------------------------------------
# Batched trace replay
# ---------------------------------------------------------------------------


def _sweep_group(trace, topo, names, pols, pm):
    """Replay ``trace`` once for a static-structure group of B policies."""
    proto = _canonical_proto(pols[0])
    B = len(pols)
    n_links = topo.n_links
    params = stack_params(pols)
    nets = jax.vmap(lambda p: S.init_net(n_links, proto, params=p))(params)
    run = _compiled_sweep_chunk(proto, pm, n_links)

    ready = np.zeros((B, topo.n_nodes), np.float64)
    busy = 0.0
    lat_sum = np.zeros(B)
    lat_max = np.zeros(B)
    n_msgs = 0

    for step in trace.steps:
        if step.compute_nodes is not None and len(step.compute_nodes):
            ready[:, step.compute_nodes] += step.compute_secs[None, :]
            busy += float(step.compute_secs.sum())
        if step.msgs is not None and len(step.msgs):
            src = step.msgs[:, 0]
            dst = step.msgs[:, 1]
            nbytes = step.msgs[:, 2].astype(np.float64)
            links, dirs, nhops = topo.routes(src, dst)
            # per-lane injection order: each policy's latency feedback gives
            # it a different per-node clock, hence a different replay order
            t_inj = ready[:, src]                           # (B, M)
            order = np.argsort(t_inj, axis=1, kind="stable")
            dst_b = dst[order]
            msgs = _pad_msgs_batch(
                links[order], dirs[order], nhops[order],
                np.take_along_axis(t_inj, order, axis=1), nbytes[order])
            nets, (delivery, lat) = run(nets, params, msgs)
            M = len(src)
            delivery = np.asarray(delivery).T[:, :M]        # (B, M)
            lat_np = np.asarray(lat).T[:, :M]
            np.maximum.at(ready, (np.arange(B)[:, None], dst_b), delivery)
            lat_sum += lat_np.sum(1)
            lat_max = np.maximum(lat_max, lat_np.max(1, initial=0.0))
            n_msgs += M
        if step.barrier:
            nodes = trace.nodes
            ready[:, nodes] = ready[:, nodes].max(axis=1, keepdims=True)

    t_end = (ready[:, trace.nodes].max(1) if len(trace.nodes)
             else np.zeros(B))
    out = {}
    for b, name in enumerate(names):
        net_b = jax.tree.map(lambda x: x[b], nets)
        out[name] = S.summarize(net_b, float(t_end[b]), busy,
                                float(lat_sum[b]), float(lat_max[b]),
                                n_msgs, pols[b], pm, topo)
    return out


def sweep_policies(trace, topo, policies: dict, pm: PowerModel | None = None,
                   max_group: int | None = None) -> dict:
    """Evaluate every policy in {name: Policy} over one trace, batched.

    Policies are grouped by static structure (``eee.static_key``); each
    group replays the trace ONCE with a leading policy axis of width B and
    a single compiled scan per chunk.  Returns {name: SimResult} in the
    caller's insertion order — results match serial
    ``simulator.simulate_trace`` per policy to float64 tolerance.

    ``max_group`` caps the batch width (splits big groups), bounding device
    memory at paper scale: predictor state is O(B * n_links * hist_bins).
    """
    pm = pm or PowerModel()
    out = {}
    for names in group_policies(policies):
        cap = max_group or len(names)
        for i in range(0, len(names), cap):
            chunk = names[i:i + cap]
            out.update(_sweep_group(trace, topo, chunk,
                                    [policies[n] for n in chunk], pm))
    return {name: out[name] for name in policies}
