"""Decoupled (per-port) policy evaluation — the fast sweep path.

Given per-link busy intervals recorded from one coupled baseline run
(policy='none'), evaluate any number of PDT policies WITHOUT re-simulating
the network: each link's EEE state machine depends only on its own arrival
process once latency feedback is ignored (first-order approximation,
quantified against the coupled simulator in benchmarks/bench_decoupled.py).

Pipeline (all Pallas-kernel backed):
  events -> per-port (gap, duration) streams   [host sort]
         -> hist_update kernel  -> inactivity histograms
         -> tpdt_select kernel  -> per-port PerfBound t_PDT snapshot
         -> port_energy kernel  -> energy / hits / misses per port
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import perfbound as pb
from repro.core.eee import Policy, PowerModel
from repro.kernels import ops


def events_to_streams(events, n_links, t_end):
    """events: list of (link, t_start, t_end) host arrays from
    ``simulate_trace(..., collect_events=True)``.

    Returns gaps (E,P) f32, durs (E,P) f32, tail (P,) f32 — per-link idle
    gap before each busy interval (merged across both directions) and the
    closing idle tail up to ``t_end``.
    """
    lp = np.concatenate([e[0] for e in events])
    ts = np.concatenate([e[1] for e in events])
    te = np.concatenate([e[2] for e in events])
    order = np.lexsort((ts, lp))
    lp, ts, te = lp[order], ts[order], te[order]

    counts = np.bincount(lp, minlength=n_links)
    E = max(int(counts.max(initial=1)), 1)
    P = n_links
    gaps = np.zeros((E, P), np.float32)
    durs = np.zeros((E, P), np.float32)
    tail = np.full((P,), t_end, np.float32)

    pos = np.zeros(P, np.int64)
    last = np.zeros(P, np.float64)
    # merge overlapping intervals per link (full-duplex overlap)
    for l, s, e in zip(lp, ts, te):
        if s < last[l]:  # overlaps previous busy window: extend it
            if e > last[l]:
                durs[pos[l] - 1, l] += e - last[l]
                last[l] = e
            continue
        gaps[pos[l], l] = s - last[l]
        durs[pos[l], l] = e - s
        pos[l] += 1
        last[l] = e
    tail = (t_end - last).astype(np.float32)
    return jnp.asarray(gaps), jnp.asarray(durs), jnp.asarray(tail)


def evaluate_fixed(gaps, durs, tail, t_pdt, policy: Policy,
                   pm: PowerModel, use_ref=False):
    """Evaluate a per-port (or scalar) t_PDT assignment.  Returns dict.

    Dual-capable policies (``dual``/``coalesce``/``perfbound_dual``)
    evaluate the two-row ladder: gaps outlasting the demotion timer land
    in the deep row's time/energy accounts.
    """
    P = gaps.shape[1]
    tpdt = jnp.broadcast_to(jnp.asarray(t_pdt, jnp.float32), (P,))
    st, st2 = policy.state, policy.deep
    t_dst = policy.t_dst if policy.dual_capable else float("inf")
    hold = policy.hold_delay if policy.kind == "precoalesce" else 0.0
    out = ops.port_energy_op(gaps, durs, tpdt, tail, t_w=st.t_w, t_s=st.t_s,
                             t_w2=st2.t_w, t_s2=st2.t_s, t_dst=t_dst,
                             hold=hold, use_ref=use_ref)
    link_energy = 2 * pm.port_power * (
        out["time_wake"].sum() + st.power_frac * out["time_sleep"].sum()
        + st2.power_frac * out["time_sleep2"].sum())
    return dict(out, link_energy=float(link_energy),
                wake_time=float(out["time_wake"].sum()),
                sleep_time=float(out["time_sleep"].sum()),
                sleep2_time=float(out["time_sleep2"].sum()))


def perfbound_snapshot_tpdt(gaps, t_elapsed, hop_mean, policy: Policy,
                            use_ref=False):
    """One-shot PerfBound prediction from the full gap history (the
    'periodic batched recalculation' mode of §3.2, kernel-accelerated)."""
    counts, sums = ops.hist_update_op(
        gaps, n_bins=policy.hist_bins, bin_width=policy.hist_bin_width,
        log_bins=policy.hist_log_bins, log_min=policy.hist_log_min,
        log_max=policy.hist_log_max, use_ref=use_ref)
    l = policy.bound / max(hop_mean, 1.0)
    N = jnp.full(counts.shape[:1], l * t_elapsed / policy.state.t_w,
                 jnp.float32)
    centers = pb.bin_centers(policy).astype(jnp.float32)
    total = counts.sum(-1)
    return ops.tpdt_select_op(counts, sums, N, total, centers,
                              max_tpdt=policy.max_tpdt,
                              tpdt_init=policy.tpdt_init, use_ref=use_ref)


def sweep_policies(events, n_links, t_end, tpdt_values, policy: Policy,
                   pm: PowerModel | None = None):
    """Fast sweep of fixed t_PDT values over one recorded baseline run."""
    pm = pm or PowerModel()
    gaps, durs, tail = events_to_streams(events, n_links, t_end)
    return {t: evaluate_fixed(gaps, durs, tail, t, policy, pm)
            for t in tpdt_values}
