"""Decoupled (per-port) policy evaluation — the fast sweep path.

Given per-link busy intervals recorded from one coupled baseline run
(policy='none'), evaluate any number of PDT policies WITHOUT re-simulating
the network: each link's EEE state machine depends only on its own arrival
process once latency feedback is ignored (first-order approximation,
quantified against the coupled simulator in benchmarks/bench_decoupled.py).

Pipeline (all Pallas-kernel backed):
  events -> per-port (gap, duration) streams   [host sort]
         -> hist_update kernel  -> inactivity histograms
         -> tpdt_select kernel  -> per-port PerfBound t_PDT snapshot
         -> port_energy kernel  -> energy / hits / misses per port
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import perfbound as pb
from repro.core.eee import Policy, PowerModel
from repro.kernels import ops


def _sorted_events(events):
    lp = np.concatenate([np.asarray(e[0]) for e in events]) \
        if events else np.zeros(0, np.int64)
    ts = np.concatenate([np.asarray(e[1], np.float64) for e in events]) \
        if events else np.zeros(0, np.float64)
    te = np.concatenate([np.asarray(e[2], np.float64) for e in events]) \
        if events else np.zeros(0, np.float64)
    order = np.lexsort((ts, lp))
    return lp[order], ts[order], te[order]


def _events_to_streams_ref(events, n_links, t_end):
    """Scalar reference of the merge (the pre-vectorization loop) — kept
    as the semantics oracle for tests/test_decoupled.py."""
    lp, ts, te = _sorted_events(events)
    counts = np.bincount(lp, minlength=n_links)
    E = max(int(counts.max(initial=1)), 1)
    P = n_links
    gaps = np.zeros((E, P), np.float32)
    durs = np.zeros((E, P), np.float32)

    pos = np.zeros(P, np.int64)
    last = np.zeros(P, np.float64)
    # merge overlapping intervals per link (full-duplex overlap)
    for l, s, e in zip(lp, ts, te):
        if s < last[l]:  # overlaps previous busy window: extend it
            if e > last[l]:
                durs[pos[l] - 1, l] += e - last[l]
                last[l] = e
            continue
        gaps[pos[l], l] = s - last[l]
        durs[pos[l], l] = e - s
        pos[l] += 1
        last[l] = e
    tail = (t_end - last).astype(np.float32)
    return gaps, durs, tail


def events_to_streams(events, n_links, t_end):
    """events: list of (link, t_start, t_end) host arrays from
    ``simulate_trace(..., collect_events=True)``.

    Returns gaps (E,P) f32, durs (E,P) f32, tail (P,) f32 — per-link idle
    gap before each busy interval (merged across both directions) and the
    closing idle tail up to ``t_end``.

    Fully vectorized (lexsort + segmented prefix maxima); bit-identical to
    the scalar merge loop it replaced (``_events_to_streams_ref``): the
    per-link ``last`` watermark is a running max of interval ends, so
    run starts, per-run rows, and the f64->f32 rounding chain of repeated
    run extensions all fall out of prefix ops.
    """
    lp, ts, te = _sorted_events(events)
    counts = np.bincount(lp, minlength=n_links)
    E = max(int(counts.max(initial=1)), 1)
    P = n_links
    gaps = np.zeros((E, P), np.float32)
    durs = np.zeros((E, P), np.float32)
    last_fin = np.zeros(P, np.float64)
    n = lp.size
    if n == 0:
        return (jnp.asarray(gaps), jnp.asarray(durs),
                jnp.asarray((t_end - last_fin).astype(np.float32)))

    idx = np.arange(n)
    grp_start = np.empty(n, bool)
    grp_start[0] = True
    grp_start[1:] = lp[1:] != lp[:-1]
    start = np.maximum.accumulate(np.where(grp_start, idx, 0))

    # last_before[i] = the scalar loop's ``last[l]`` seen by event i: the
    # running max of earlier interval ends in the link group, clamped >=0.
    # Exclusive shift within the group, then a segmented inclusive cummax
    # by logarithmic doubling.
    prev = np.empty(n, np.float64)
    prev[0] = 0.0
    prev[1:] = te[:-1]
    prev[grp_start] = 0.0
    last_before = np.maximum(prev, 0.0)
    d = 1
    while d < n:
        ok = idx >= start + d
        cand = np.where(ok, np.concatenate(
            [np.full(d, -np.inf), last_before[:-d]]), -np.inf)
        last_before = np.maximum(last_before, cand)
        d *= 2

    # run segmentation: every group's first event opens a run (s >= 0)
    is_new = ts >= last_before
    run = np.cumsum(is_new) - 1          # global run id
    row = run - run[start]               # per-link row = scalar pos[l]

    gaps[row[is_new], lp[is_new]] = ts[is_new] - last_before[is_new]
    durs[row[is_new], lp[is_new]] = te[is_new] - ts[is_new]

    # overlap extensions: apply in lockstep rank rounds so repeated
    # extensions of one run replay the exact f32 += rounding sequence
    ext = np.flatnonzero(~is_new & (te > last_before))
    if ext.size:
        er = run[ext]
        first = np.empty(ext.size, bool)
        first[0] = True
        first[1:] = er[1:] != er[:-1]
        rank = np.arange(ext.size) - np.maximum.accumulate(
            np.where(first, np.arange(ext.size), 0))
        for r in range(int(rank.max()) + 1):
            sel = ext[rank == r]
            durs[row[sel], lp[sel]] += te[sel] - last_before[sel]

    np.maximum.at(last_fin, lp, te)
    tail = (t_end - last_fin).astype(np.float32)
    return jnp.asarray(gaps), jnp.asarray(durs), jnp.asarray(tail)


def evaluate_fixed(gaps, durs, tail, t_pdt, policy: Policy,
                   pm: PowerModel, use_ref=False):
    """Evaluate a per-port (or scalar) t_PDT assignment.  Returns dict.

    Dual-capable policies (``dual``/``coalesce``/``perfbound_dual``)
    evaluate the two-row ladder: gaps outlasting the demotion timer land
    in the deep row's time/energy accounts.
    """
    P = gaps.shape[1]
    tpdt = jnp.broadcast_to(jnp.asarray(t_pdt, jnp.float32), (P,))
    st, st2 = policy.state, policy.deep
    t_dst = policy.t_dst if policy.dual_capable else float("inf")
    hold = policy.hold_delay if policy.kind == "precoalesce" else 0.0
    out = ops.port_energy_op(gaps, durs, tpdt, tail, t_w=st.t_w, t_s=st.t_s,
                             t_w2=st2.t_w, t_s2=st2.t_s, t_dst=t_dst,
                             hold=hold, use_ref=use_ref)
    link_energy = 2 * pm.port_power * (
        out["time_wake"].sum() + st.power_frac * out["time_sleep"].sum()
        + st2.power_frac * out["time_sleep2"].sum())
    return dict(out, link_energy=float(link_energy),
                wake_time=float(out["time_wake"].sum()),
                sleep_time=float(out["time_sleep"].sum()),
                sleep2_time=float(out["time_sleep2"].sum()))


def perfbound_snapshot_tpdt(gaps, t_elapsed, hop_mean, policy: Policy,
                            use_ref=False):
    """One-shot PerfBound prediction from the full gap history (the
    'periodic batched recalculation' mode of §3.2, kernel-accelerated)."""
    counts, sums = ops.hist_update_op(
        gaps, n_bins=policy.hist_bins, bin_width=policy.hist_bin_width,
        log_bins=policy.hist_log_bins, log_min=policy.hist_log_min,
        log_max=policy.hist_log_max, use_ref=use_ref)
    l = policy.bound / max(hop_mean, 1.0)
    N = jnp.full(counts.shape[:1], l * t_elapsed / policy.state.t_w,
                 jnp.float32)
    centers = pb.bin_centers(policy).astype(jnp.float32)
    total = counts.sum(-1)
    return ops.tpdt_select_op(counts, sums, N, total, centers,
                              max_tpdt=policy.max_tpdt,
                              tpdt_init=policy.tpdt_init, use_ref=use_ref)


def sweep_policies(events, n_links, t_end, tpdt_values, policy: Policy,
                   pm: PowerModel | None = None):
    """Fast sweep of fixed t_PDT values over one recorded baseline run."""
    pm = pm or PowerModel()
    gaps, durs, tail = events_to_streams(events, n_links, t_end)
    return {t: evaluate_fixed(gaps, durs, tail, t, policy, pm)
            for t in tpdt_values}
