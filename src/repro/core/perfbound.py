"""PerfBound and PerfBoundCorrect predictor state + math (paper §2.5, §3.4).

All state lives in dense per-link arrays so the whole network's predictors
update in a few scatters per simulated message.  The same functions serve as
the pure-jnp oracle for the Pallas kernels (``repro.kernels.ref`` re-exports).

Paper mapping
-------------
* inactivity histogram: ``counts``/``sums`` (B bins; per-bin value sums so
  t_PDT = *mean* of the selected bin, as the paper specifies).
* three management modes (§3.2/§4): keep_all, self_clear (reset every
  ``hist_clear_n`` samples), circular (ring of the last ``ring_n`` samples
  with O(1) add/evict).
* hop-distance correction: per-link histogram of remaining-hops of forwarded
  packets; ``l = bound * sum_i p_i / h_i`` (Eq. 1).
* degradation budget: ``N = l * X / t_w`` with X = wall-time covered by the
  current histogram window.
* PerfBoundCorrect (§3.4): ``n_r``-slot shift register of hit/miss outcomes +
  slot-aligned log-ratio store; ``cf = miss% * geomean(ratios)``;
  ``t_PDT' = min(t_PDT * (1 + cf), max_tpdt)`` (interpretation notes in
  DESIGN.md §4).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.eee import policy_params

MAXH = 7  # hop-count histogram rows 0..6 (Megafly max 5, fat-tree 6)


def _params(policy, params):
    """Numeric parameter vector: the policy's own scalars by default, or a
    caller-supplied dict (possibly of traced per-lane values) for the
    batched sweep.  Static structure always comes from ``policy``."""
    return policy_params(policy) if params is None else params


def _log(x):
    # python floats keep the exact libm constant-folding of the serial path
    return math.log(x) if isinstance(x, (int, float)) else jnp.log(x)


# ---------------------------------------------------------------------------
# Binning
# ---------------------------------------------------------------------------


def bin_index(gap, policy, params=None):
    """gap (seconds) -> bin id in [0, B)."""
    p = _params(policy, params)
    B = policy.hist_bins
    if policy.hist_log_bins:
        lo, hi = _log(p["hist_log_min"]), _log(p["hist_log_max"])
        x = (jnp.log(jnp.maximum(gap, p["hist_log_min"])) - lo) / (hi - lo)
        return jnp.clip((x * B).astype(jnp.int32), 0, B - 1)
    return jnp.clip((gap / p["hist_bin_width"]).astype(jnp.int32), 0, B - 1)


def bin_centers(policy, params=None):
    p = _params(policy, params)
    B = policy.hist_bins
    if policy.hist_log_bins:
        if isinstance(p["hist_log_min"], (int, float)):
            lo, hi = math.log(p["hist_log_min"]), math.log(p["hist_log_max"])
            edges = np.exp(np.linspace(lo, hi, B + 1))
            return jnp.asarray(np.sqrt(edges[:-1] * edges[1:]))
        lo, hi = jnp.log(p["hist_log_min"]), jnp.log(p["hist_log_max"])
        edges = jnp.exp(lo + (hi - lo) * jnp.arange(B + 1) / B)
        return jnp.sqrt(edges[:-1] * edges[1:])
    return (jnp.arange(B) + 0.5) * p["hist_bin_width"]


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------


def init_state(n_links, policy, params=None):
    """Predictor state for ``n_links`` (+dummy) rows.

    Non-adaptive kinds without ``record_hist`` carry ONLY the ``tpdt``
    vector — the histogram/hop arrays are dead state for them, and at
    batched-sweep scale (B lanes x P links x hist_bins f64) they dominate
    device memory.
    """
    P, B = n_links, policy.hist_bins
    st = {
        "tpdt": jnp.full((P,), _initial_tpdt(policy, params), jnp.float64),
    }
    if not (policy.adaptive or policy.record_hist):
        return st
    st.update(
        counts=jnp.zeros((P, B), jnp.float64),
        sums=jnp.zeros((P, B), jnp.float64),
        total=jnp.zeros((P,), jnp.int64),
        win_start=jnp.zeros((P,), jnp.float64),
        hops=jnp.zeros((P, MAXH), jnp.int64),
    )
    if policy.kind in ("perfbound_dual", "predict"):
        p = _params(policy, params)
        st["t_dst"] = jnp.full((P,), p["t_dst"], jnp.float64)
    if policy.kind == "predict":
        st["ewma"] = jnp.zeros((P,), jnp.float64)
    if policy.hist_mode == "circular":
        R = policy.ring_n
        st["ring_bin"] = jnp.full((P, R), -1, jnp.int32)
        st["ring_val"] = jnp.zeros((P, R), jnp.float64)
        st["ring_time"] = jnp.zeros((P, R), jnp.float64)
        st["ring_head"] = jnp.zeros((P,), jnp.int32)
        st["ring_fill"] = jnp.zeros((P,), jnp.int32)
    if policy.kind == "perfbound_correct":
        st["reg"] = jnp.zeros((P,), jnp.uint32)
        st["ratio_log"] = jnp.zeros((P, policy.n_r), jnp.float64)
        st["reg_head"] = jnp.zeros((P,), jnp.int32)
        st["n_seen"] = jnp.zeros((P,), jnp.int32)
    return st


def _initial_tpdt(policy, params=None):
    p = _params(policy, params)
    if policy.kind == "none":
        return jnp.inf
    if policy.kind in ("fixed", "dual", "coalesce", "precoalesce", "predict"):
        # predict starts dual-like: the forecaster takes over per port as
        # soon as the first gap lands in its histogram
        return p["t_pdt"]
    return p["tpdt_init"]


# ---------------------------------------------------------------------------
# Updates (batched over K link slots; links within a batch must be distinct,
# which minimal routing guarantees for the hops of one message — and which
# the wavefront executor's link-disjoint waves extend to the (m, H) slots
# of a whole wave of messages at once)
# ---------------------------------------------------------------------------


def record_gaps(st, lp, gap, t_now, active, policy, params=None):
    """Insert inactivity gaps.  lp,gap,t_now,active: (K,) or (m, H)."""
    p = _params(policy, params)
    do = active & (gap > 0)
    b = bin_index(gap, policy, p)
    g = jnp.where(do, gap, 0.0)
    inc = do.astype(st["counts"].dtype)

    if policy.hist_mode == "circular":
        R = policy.ring_n
        head = st["ring_head"][lp]
        full = st["ring_fill"][lp] >= R
        old_b = st["ring_bin"][lp, head]
        old_v = st["ring_val"][lp, head]
        evict = do & full & (old_b >= 0)
        # evict oldest, insert new (O(1))
        counts = st["counts"].at[lp, old_b].add(-evict.astype(jnp.float64))
        sums = st["sums"].at[lp, old_b].add(jnp.where(evict, -old_v, 0.0))
        counts = counts.at[lp, b].add(inc)
        sums = sums.at[lp, b].add(g)
        st = dict(
            st, counts=counts, sums=sums,
            ring_bin=st["ring_bin"].at[lp, head].set(
                jnp.where(do, b, st["ring_bin"][lp, head])),
            ring_val=st["ring_val"].at[lp, head].set(
                jnp.where(do, g, old_v)),
            ring_time=st["ring_time"].at[lp, head].set(
                jnp.where(do, t_now, st["ring_time"][lp, head])),
            ring_head=st["ring_head"].at[lp].set(
                jnp.where(do, (head + 1) % R, head)),
            ring_fill=st["ring_fill"].at[lp].add(
                (do & ~full).astype(jnp.int32)),
            total=st["total"].at[lp].add(do.astype(jnp.int64)),
        )
        # X window start = timestamp of the oldest live element
        oldest = jnp.where(st["ring_fill"][lp] >= R,
                           st["ring_time"][lp, st["ring_head"][lp]],
                           st["ring_time"][lp, 0])
        st["win_start"] = st["win_start"].at[lp].set(
            jnp.where(active, oldest, st["win_start"][lp]))
        return st

    counts, sums = st["counts"], st["sums"]
    if policy.hist_decay < 1.0:
        # exponential recency bias (beyond-paper, paper §5 future work):
        # old evidence fades at ``hist_decay`` per new sample on that port
        d = jnp.where(do, p["hist_decay"], 1.0)[..., None]
        counts = counts.at[lp].multiply(d)
        sums = sums.at[lp].multiply(d)
        # the budget window X follows the effective sample horizon
        # (~1/(1-decay) samples): pull win_start toward t_now at the same
        # rate so N = l*X/t_w shrinks consistently with the history
        ws = st["win_start"][lp]
        new_ws = ws + (1 - p["hist_decay"]) * (t_now - ws)
        st = dict(st, win_start=st["win_start"].at[lp].set(
            jnp.where(do, new_ws, ws)))
    counts = counts.at[lp, b].add(inc)
    sums = sums.at[lp, b].add(g)
    total = st["total"].at[lp].add(do.astype(jnp.int64))
    st = dict(st, counts=counts, sums=sums, total=total)

    if policy.hist_mode == "self_clear":
        clear = active & (total[lp] >= p["hist_clear_n"])
        st["counts"] = st["counts"].at[lp].set(
            jnp.where(clear[..., None], 0.0, st["counts"][lp]))
        st["sums"] = st["sums"].at[lp].set(
            jnp.where(clear[..., None], 0.0, st["sums"][lp]))
        st["total"] = st["total"].at[lp].set(
            jnp.where(clear, 0, st["total"][lp]))
        st["win_start"] = st["win_start"].at[lp].set(
            jnp.where(clear, t_now, st["win_start"][lp]))
    return st


def record_hops(st, lp, rem_hops, active, policy):
    h = jnp.clip(rem_hops, 0, MAXH - 1)
    return dict(st, hops=st["hops"].at[lp, h].add(active.astype(jnp.int64)))


def record_outcomes(st, lp, miss, ratio, active, policy):
    """PerfBoundCorrect shift register + ratio FIFO (slot-aligned)."""
    nr = policy.n_r
    head = st["reg_head"][lp]
    bit = jnp.uint32(1) << head.astype(jnp.uint32)
    reg = st["reg"][lp]
    new_reg = jnp.where(miss, reg | bit, reg & ~bit)
    lr = jnp.where(miss, jnp.log(jnp.maximum(ratio, 1e-12)), 0.0)
    return dict(
        st,
        reg=st["reg"].at[lp].set(jnp.where(active, new_reg, reg)),
        ratio_log=st["ratio_log"].at[lp, head].set(
            jnp.where(active, lr, st["ratio_log"][lp, head])),
        reg_head=st["reg_head"].at[lp].set(
            jnp.where(active, (head + 1) % nr, head)),
        n_seen=st["n_seen"].at[lp].set(
            jnp.where(active, jnp.minimum(st["n_seen"][lp] + 1, nr),
                      st["n_seen"][lp])),
    )


# ---------------------------------------------------------------------------
# t_PDT computation (rowwise; also the kernel oracle)
# ---------------------------------------------------------------------------


def l_factor(hops, bound):
    """hops: (..., H) counts of remaining-hop distances.  Eq. 1."""
    tot = hops.sum(-1)
    h = jnp.arange(hops.shape[-1], dtype=jnp.float64).at[0].set(1.0)
    p = hops / jnp.maximum(tot, 1)[..., None]
    l = bound * (p / h).sum(-1)
    # no history yet -> most conservative correction (distance 1)
    return jnp.where(tot > 0, l, bound)


def _suffix_sum(x):
    """Suffix (tail) accumulation along the bin axis."""
    return jnp.cumsum(x[..., ::-1], axis=-1)[..., ::-1]


def tpdt_select(counts, sums, N, total, policy, params=None, ccum=None):
    """PerfBound bin selection (vectorized over leading dims).

    From the highest bin downwards accumulate counts; pick the leftmost bin
    whose tail-accumulation is <= N; t_PDT = mean of that bin.  ``ccum``
    optionally supplies a precomputed suffix count accumulation (shared
    with ``tdst_select`` in the fused perfbound_dual path).
    """
    p = _params(policy, params)
    centers = bin_centers(policy, p)
    rcum = _suffix_sum(counts) if ccum is None else ccum
    feasible = rcum <= N[..., None]
    found = feasible.any(-1)
    j = jnp.argmax(feasible, axis=-1)
    cj = jnp.take_along_axis(counts, j[..., None], -1)[..., 0]
    sj = jnp.take_along_axis(sums, j[..., None], -1)[..., 0]
    mean = jnp.where(cj > 0, sj / jnp.maximum(cj, 1e-30), centers[j])
    t = jnp.where(found, mean, p["max_tpdt"])
    # empty-histogram fallback: no samples yet (total == 0) OR no live mass
    # (total > 0 but every count zeroed, e.g. an externally invalidated
    # histogram) — bin 0 would otherwise look feasible with an empty-bin
    # "mean" of its center, a bogusly aggressive timer
    return jnp.where((total > 0) & (rcum[..., 0] > 0), t, p["tpdt_init"])


def deep_breakeven(params) -> jnp.ndarray:
    """Residual idle time beyond the demotion point that amortizes a deep
    (row-2) wake: the extra wake transition plus the second down transition
    at wake power must be repaid by the deeper power floor.

        R* = ((t_w2 - t_w) + t_s2 * (1 - frac)) / (frac - frac2)

    Degenerate ladders (frac2 >= frac, i.e. deep saves nothing) price the
    break-even at +inf — demotion never pays.
    """
    gain = params["power_frac"] - params["power_frac2"]
    cost = (params["t_w2"] - params["t_w"]) \
        + params["t_s2"] * (1.0 - params["power_frac"])
    return jnp.where(gain > 0, cost / jnp.maximum(gain, 1e-30), jnp.inf)


def tdst_select(counts, sums, tpdt, r_star, total, policy, params=None,
                ccum=None):
    """Demotion-threshold selection from the inactivity histogram.

    For each candidate bin center T the histogram's suffix mass estimates
    the conditional residual idle E[gap - T | gap >= T]; the leftmost
    (earliest-demoting) T whose residual covers the break-even ``r_star``
    wins, and the threshold converts to a timer past the sleep deadline:
    t_dst = max(T - t_pdt, 0).  No feasible bin -> +inf (never demote);
    no history yet -> the policy's initial ``t_dst``.
    """
    p = _params(policy, params)
    centers = bin_centers(policy, p)
    if ccum is None:
        ccum = _suffix_sum(counts)
    scum = _suffix_sum(sums)
    resid = scum / jnp.maximum(ccum, 1e-30) - centers
    feasible = (ccum > 0) & (resid >= r_star[..., None])
    found = feasible.any(-1)
    j = jnp.argmax(feasible, axis=-1)
    T = centers[j]
    t = jnp.where(found, jnp.maximum(T - tpdt, 0.0), jnp.inf)
    # same empty-histogram fallback as tpdt_select: a massless histogram
    # (total == 0, or invalidated counts) keeps the initial timer instead
    # of pinning demotion off at +inf
    return jnp.where((total > 0) & (ccum[..., 0] > 0), t, p["t_dst"])


def compute_tdst(st, lp, tpdt_new, policy, params=None):
    """Recalculate the per-port demotion timer for rows ``lp`` given the
    freshly selected ``tpdt_new``.  (K,) -> (K,)."""
    p = _params(policy, params)
    r_star = jnp.broadcast_to(deep_breakeven(p), lp.shape)
    return tdst_select(st["counts"][lp], st["sums"][lp], tpdt_new, r_star,
                       st["total"][lp], policy, p)


def compute_tpdt_tdst(st, lp, t_now, t_w, policy, params=None):
    """Fused perfbound_dual update: ONE set of histogram gathers and one
    shared suffix-count accumulation feed both the t_PDT selection and the
    demotion-threshold selection — the per-message hot path would
    otherwise do both twice.  Returns (t_pdt, t_dst), each (K,)."""
    p = _params(policy, params)
    counts = st["counts"][lp]
    sums = st["sums"][lp]
    total = st["total"][lp]
    ccum = _suffix_sum(counts)
    X = jnp.maximum(t_now - st["win_start"][lp], 0.0)
    l = l_factor(st["hops"][lp], p["bound"])
    N = l * X / t_w
    t = tpdt_select(counts, sums, N, total, policy, p, ccum=ccum)
    r_star = jnp.broadcast_to(deep_breakeven(p), lp.shape)
    td = tdst_select(counts, sums, t, r_star, total, policy, p, ccum=ccum)
    return t, td


def sleep_breakeven(params) -> jnp.ndarray:
    """Gap length at which entering the (row-1) sleep state at onset pays:
    the down transition at wake power plus the wake penalty must be repaid
    by the idle power floor,

        g* = t_s + (t_w + sync) / (1 - frac).
    """
    return params["t_s"] + (params["t_w"] + params["sync_overhead"]) \
        / (1.0 - params["power_frac"])


def forecast_update(st, lp, gap, active, policy, params=None):
    """``predict`` forecaster (arXiv 1503.02843 flavor): predict the NEXT
    inactivity gap per port and schedule the timers ahead of it.

    Two estimators share the histogram state ``record_gaps`` already
    maintains.  An EWMA of observed gaps (weight ``forecast_weight`` on the
    newest) tracks drifting traffic; when one histogram bin holds at least
    ``period_conf`` of the live mass — periodic BSP traffic concentrates
    its inter-burst gap in one bin — the mode bin's mean overrides the
    EWMA (the cheap periodogram: the dominant frequency of a periodic
    arrival process IS its modal gap).

    The predicted gap then prices the FSM ladder *proactively*: if it
    covers ``forecast_margin`` x the sleep break-even the port sleeps at
    onset (t_pdt -> 0), and if it also covers the demotion break-even the
    port demotes at onset (t_dst -> 0).  When the forecast does NOT clear
    a margin the timer falls back to the policy's own reactive value —
    predict degrades gracefully to ``dual`` on unpredictable traffic
    instead of holding awake, so a large ``forecast_margin`` (never
    confident) and ``forecast_weight == 0`` (forecaster off) both
    reproduce ``dual`` bit-for-bit.

    Call AFTER ``record_gaps`` (the new gap is already in the histogram).
    Returns (tpdt_new, t_dst_new, ewma_new), each (K,).
    """
    p = _params(policy, params)
    obs = active & (gap > 0)
    w = p["forecast_weight"]
    total = st["total"][lp]
    ewma_old = st["ewma"][lp]
    first = obs & (total <= 1)
    ewma_new = jnp.where(
        first, gap,
        jnp.where(obs, (1.0 - w) * ewma_old + w * gap, ewma_old))

    counts = st["counts"][lp]
    sums = st["sums"][lp]
    mass = counts.sum(-1)
    j = jnp.argmax(counts, axis=-1)
    cj = jnp.take_along_axis(counts, j[..., None], -1)[..., 0]
    sj = jnp.take_along_axis(sums, j[..., None], -1)[..., 0]
    mode_mean = jnp.where(cj > 0, sj / jnp.maximum(cj, 1e-30), 0.0)
    peaked = (mass > 0) & (cj >= p["period_conf"] * mass)
    ghat = jnp.where(peaked, mode_mean, ewma_new)

    pred_on = (w > 0) & (total > 0)
    b1 = sleep_breakeven(p)
    r_star = deep_breakeven(p)
    sleep_now = ghat >= p["forecast_margin"] * b1
    deep_now = ghat >= p["forecast_margin"] * (b1 + r_star)
    tpdt_new = jnp.where(pred_on & sleep_now, 0.0, p["t_pdt"])
    tdst_new = jnp.where(pred_on & deep_now, 0.0, p["t_dst"])
    return tpdt_new, tdst_new, ewma_new


def pbc_cf(reg, ratio_log, n_seen, policy):
    """Corrective factor cf = miss% * geomean(miss ratios)."""
    nr = policy.n_r
    bits = (reg[..., None] >> jnp.arange(nr, dtype=jnp.uint32)) & 1
    bits = bits.astype(jnp.float64)
    miss_cnt = bits.sum(-1)
    n = jnp.maximum(n_seen, 1)
    miss_pct = miss_cnt / n
    gmean = jnp.exp((bits * ratio_log).sum(-1) / jnp.maximum(miss_cnt, 1.0))
    return miss_pct * jnp.where(miss_cnt > 0, gmean, 1.0)


def compute_tpdt(st, lp, t_now, t_w, policy, params=None):
    """Recalculate t_PDT for link rows ``lp`` at time ``t_now``.  (K,)->(K,)."""
    p = _params(policy, params)
    counts = st["counts"][lp]
    sums = st["sums"][lp]
    total = st["total"][lp]
    X = jnp.maximum(t_now - st["win_start"][lp], 0.0)
    l = l_factor(st["hops"][lp], p["bound"])
    N = l * X / t_w
    t = tpdt_select(counts, sums, N, total, policy, p)
    if policy.kind == "perfbound_correct":
        cf = pbc_cf(st["reg"][lp], st["ratio_log"][lp], st["n_seen"][lp],
                    policy)
        if policy.cf_mode == "uplift":
            t = t * (1.0 + cf)
        else:
            t = t * jnp.maximum(cf, 1.0)
        t = jnp.minimum(t, p["max_tpdt"])
    return t


def compute_tpdt_all(st, t_now, t_w, policy, params=None):
    """Batched periodic recalculation over every link (kernel-accelerated
    variant lives in repro.kernels.ops.tpdt_select_op)."""
    P = st["counts"].shape[0]
    return compute_tpdt(st, jnp.arange(P), t_now, t_w, policy, params)
