"""whisper-tiny [audio] — enc-dec, conv frontend stubbed. [arXiv:2212.04356; unverified]

4L d_model=384 6H (MHA kv=6) d_ff=1536 vocab=51865
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper-tiny",
        family="encdec",
        num_layers=4,             # decoder layers
        num_encoder_layers=4,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,         # padded to vocab_pad_multiple for TP
        frontend="audio",
        rope=False,               # learned positions
        max_positions=36864,      # covers decode_32k cache + sampling margin
        norm="layernorm",
        act="gelu",
        tie_embeddings=True,
    )
)
