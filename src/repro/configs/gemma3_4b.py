"""gemma3-4b [dense] — 5:1 local:global sliding window, 128k context.

[hf:google/gemma-3-1b-pt; unverified]
34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma3-4b",
        family="dense",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        sliding_window=1024,
        global_layer_every=6,  # 5 local : 1 global
        qk_norm=True,
        tie_embeddings=True,
        act="gelu",
        rope_theta=1_000_000.0,
    )
)
