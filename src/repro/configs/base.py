"""Configuration system: model configs, input shapes, and the arch registry.

Every assigned architecture provides a full-size config (exercised only through
the abstract dry-run) and a reduced ``smoke`` config (instantiated on CPU in
tests).  Configs are frozen dataclasses so they hash and are safe as jit static
arguments.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    # hybrid: apply the shared attention block before mamba layer i when
    # i % attn_every == 0 (Zamba2-style shared transformer block)
    attn_every: int = 0

    # --- RWKV ---
    rwkv_head_dim: int = 64

    # --- attention pattern ---
    sliding_window: int = 0          # 0 -> full attention
    global_layer_every: int = 0      # gemma3: every k-th layer is global
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True
    rope: bool = True
    rope_theta: float = 10_000.0

    # --- MLP ---
    act: str = "swiglu"  # swiglu | sq_relu | gelu

    # --- encoder-decoder ---
    num_encoder_layers: int = 0      # >0 -> encoder-decoder (whisper)
    max_positions: int = 0           # learned-position table size (rope=False)

    # --- modality frontend (stubbed: embeddings come in via input_specs) ---
    frontend: str = "none"           # none | vision | audio
    num_patches: int = 0             # vlm: patch-embed prefix length

    # --- embeddings ---
    tie_embeddings: bool = True
    vocab_pad_multiple: int = 128

    # --- norm ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm

    # --- numerics / scan ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk_q: int = 512          # chunked (flash-style) attention block sizes
    attn_chunk_kv: int = 1024
    attn_direct_max_seq: int = 2048  # below this, use direct attention
    ssm_chunk: int = 128             # mamba2 / rwkv6 chunk length
    # attention implementation for S>1 self-attention:
    #   'jax'    — pure-JAX chunked online-softmax (differentiable default)
    #   'pallas' — VMEM-tiled flash kernel (TPU; interpret-mode on CPU)
    #   'stub'   — HBM-contract stand-in (reads q/k/v, writes o) used by
    #              the dry-run to measure the Pallas kernel's memory term
    attn_impl: str = "jax"
    # remat policy for the scanned layer body (perf lever, §Perf):
    #   'full'      — checkpoint everything (baseline; bwd re-runs the
    #                 whole layer INCLUDING its TP all-reduces)
    #   'save_coll' — save the post-collective activations (attn/moe/mlp
    #                 block outputs): bwd recompute stops at them, so the
    #                 forward TP all-reduces are not replayed
    #   'none'      — no remat (peak activation memory, fewest FLOPs)
    remat_policy: str = "full"
    # MoE dispatch: 'global' scatters every token into ONE (E, C, D)
    # buffer sharded only over experts — each device computes the FULL
    # global capacity (DP-redundant).  'dp' additionally shards the
    # capacity dim over the data axis so expert GEMMs scale with DP.
    moe_dispatch: str = "global"
    # residual-stream activation sharding between blocks:
    #   'seq'    — (batch, SEQUENCE over model, d_model) — fine for
    #              attention stacks, but time-RECURRENT stacks (SSM/RWKV
    #              chunk scans) then all-gather the stream every chunk
    #   'dmodel' — (batch, seq, D_MODEL over model) — aligns with the
    #              head/channel sharding recurrent blocks use internally
    #   'batch'  — batch only; XLA propagates TP inside the block (best
    #              for chunked-attention stacks, measured in §Perf)
    act_shard: str = "batch"
    # recurrent-core implementation (mamba2 SSD / rwkv6 WKV):
    #   'jax'    — chunked scan (differentiable default)
    #   'pallas' — VMEM-tiled SSD kernel (mamba2; oracle-recompute bwd)
    #   'stub'   — VMEM-kernel HBM contract (reads the projected inputs,
    #              writes y + final state) for dry-run bound measurement
    ssm_impl: str = "jax"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived ----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_encdec(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if a 500k-token decode is feasible (bounded KV memory)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # local:global sliding window keeps most layers' KV bounded
        return self.sliding_window > 0 and self.global_layer_every > 0

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models import model as _m
        return _m.count_params(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        from repro.models import model as _m
        return _m.count_params(self, active_only=True)

    # -- traffic sizing (scenario synthesis) ------------------------------
    def layer_param_count(self) -> int:
        """Analytic parameter count of ONE decoder block.

        Used by ``repro.scenarios.ml`` to size gradient/activation
        collectives without instantiating the model; approximate for
        hybrid families (recurrent core only), which is fine for traffic
        synthesis — payload sizes, not training math.
        """
        d, ff = self.d_model, self.d_ff
        if self.family in ("ssm", "hybrid"):
            core = 3 * d * self.d_inner + self.d_inner * d
        else:
            core = (d * self.num_heads * self.head_dim
                    + 2 * d * self.num_kv_heads * self.head_dim
                    + self.num_heads * self.head_dim * d)
        if self.num_experts:
            mlp = d * self.num_experts + 3 * d * ff * self.num_experts
        else:
            mlp = (3 if self.act == "swiglu" else 2) * d * ff
        return core + mlp

    def embed_param_count(self) -> int:
        """Embedding-table parameters (padded vocab), for weight
        distribution / setup traffic."""
        return self.padded_vocab * self.d_model

    def smoke(self) -> "ModelConfig":
        """A reduced config of the same family for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 3),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2)
            if self.num_kv_heads < self.num_heads
            else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            vocab_pad_multiple=8,
            num_patches=8 if self.frontend == "vision" else 0,
            num_encoder_layers=2 if self.is_encdec else 0,
            max_positions=128 if self.max_positions else 0,
            sliding_window=16 if self.sliding_window else 0,
            global_layer_every=self.global_layer_every and 2,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            rwkv_head_dim=16,
            attn_every=2 if self.attn_every else 0,
            ssm_chunk=8,
            attn_chunk_q=8,
            attn_chunk_kv=8,
            attn_direct_max_seq=32,
            dtype="float32",
        )
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and the reason if skipped.

    Skips follow the brief: ``long_500k`` needs a sub-quadratic backbone.
    """
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "llava_next_34b",
    "qwen3_moe_30b_a3b",
    "dbrx_132b",
    "zamba2_7b",
    "rwkv6_7b",
    "whisper_tiny",
    "gemma3_4b",
    "qwen1_5_4b",
    "qwen2_1_5b",
    "nemotron_4_15b",
]


def _load_all() -> None:
    import importlib

    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
