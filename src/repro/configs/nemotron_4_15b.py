"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="nemotron-4-15b",
        family="dense",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        act="sq_relu",
        tie_embeddings=False,
        norm="layernorm",
    )
)
