"""llava-next-34b [vlm] — anyres tiling; backbone only, patch embeds stubbed.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llava-next-34b",
        family="vlm",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        frontend="vision",
        num_patches=1024,  # anyres: base tile + 4 sub-tiles of pooled patches
        tie_embeddings=False,
        act="swiglu",
        rope_theta=5_000_000.0,
    )
)
