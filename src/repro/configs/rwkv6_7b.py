"""rwkv6-7b [ssm] — Finch, data-dependent decay. [arXiv:2404.05892; hf]

32L d_model=4096 (attn-free) d_ff=14336 vocab=65536
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,      # rwkv heads = d_model / rwkv_head_dim
        num_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        rwkv_head_dim=64,
        rope=False,
        tie_embeddings=False,
        act="sq_relu",     # rwkv channel-mix uses squared relu
        act_shard="seq",   # chunk-scan-local residuals (see §Perf cell 2)
    )
)
