"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; unverified]
81L d_model=3584 32H (MHA kv=32) d_ff=14336 vocab=32000, ssm_state=64
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        attn_every=6,  # shared transformer block applied every 6 mamba layers
        tie_embeddings=True,
        act="swiglu",
    )
)
