"""Policy search space: per-kind coarse grids + successive-halving refinement.

A :class:`KindSpace` pairs a base :class:`~repro.core.eee.Policy` (the
static structure plus any pinned numerics) with the :class:`Knob` s the
tuner may turn.  The coarse grid (round 0) is the cross product of every
knob's ``coarse`` values; refinement rounds generate AXIS-WISE
multiplicative neighbours around each survivor — knob ``k`` at value ``v``
proposes ``v/f`` and ``v*f`` with the factor shrinking geometrically per
round (``f_r = step ** 0.5**r``: ~3.16x then ~1.78x for the step=10
timer knobs, 2x then ~1.41x for step=4), narrowing toward the optimum
without the cross-product blow-up; more ``rounds`` buy finer resolution
at ~sqrt rate per round.

Everything here is static structure from the sweep engine's point of view:
every candidate of a KindSpace shares ``eee.static_key`` with its base, so
a whole coarse grid or refinement wave replays as lanes of ONE compiled
program per plan shape (DESIGN.md §7).

Candidate names are pure functions of (kind label, knob values) — the same
parameter point proposed twice (two survivors refining into each other)
dedupes by name, and a warm tuner rerun regenerates identical grids.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.core.eee import Policy, static_key


def canon(v: float) -> float:
    """Canonicalize a knob value to the candidate-NAME precision (%.6g).

    Candidate identity is the formatted name, so values must be fixed
    points of the formatting round-trip: two refinement paths that land
    ulp-apart on "the same" parameter point (e.g. ``1e-6·√10·⁴√10`` vs
    ``1e-5/⁴√10``) would otherwise share a name while carrying unequal
    Policies — and ``sweep_cells`` correctly rejects one name mapping to
    two policies across traces."""
    return float(f"{v:.6g}")


@dataclass(frozen=True)
class Knob:
    """One tunable numeric Policy field."""
    field: str
    coarse: Tuple[float, ...]      # round-0 grid values
    step: float = 10.0             # coarse spacing ratio; refinement factor
    #                                for round r is step ** 0.5**r
    lo: float = 0.0                # clamp range for refined values
    hi: float = float("inf")
    integer: bool = False          # round refined values (e.g. max_frames)

    def refine_factor(self, round_idx: int) -> float:
        return self.step ** (0.5 ** max(round_idx, 1))

    def clamp(self, v: float):
        """Bound to [lo, hi] and canonicalize to name precision — every
        value that enters a candidate (coarse or refined) passes through
        here, so name identity implies value identity."""
        v = min(max(v, self.lo), self.hi)
        return max(int(round(v)), 1) if self.integer else canon(float(v))


@dataclass(frozen=True)
class KindSpace:
    """The searchable neighbourhood of one policy kind (one static group)."""
    label: str                     # grid-name prefix, e.g. "fixed-fw"
    base: Policy                   # static structure + pinned numerics
    knobs: Tuple[Knob, ...] = ()

    def make(self, values: Dict[str, float]) -> Tuple[str, Policy]:
        """(candidate name, Policy) for one knob assignment."""
        pol = dataclasses.replace(self.base, **values) if values \
            else self.base
        args = ",".join(f"{k.field}={values[k.field]:.6g}"
                        for k in self.knobs)
        return (f"{self.label}({args})" if args else self.label), pol

    def coarse_grid(self) -> Dict[str, Tuple[Policy, Dict[str, float]]]:
        """{name: (policy, knob assignment)} — the round-0 cross product."""
        out = {}
        axes = [[(k.field, k.clamp(v)) for v in k.coarse]
                for k in self.knobs]
        for combo in itertools.product(*axes) if axes else [()]:
            values = dict(combo)
            name, pol = self.make(values)
            out[name] = (pol, values)
        return out

    def refine(self, values: Dict[str, float], round_idx: int
               ) -> Dict[str, Tuple[Policy, Dict[str, float]]]:
        """Axis-wise neighbours of one survivor at round ``round_idx``
        resolution: per knob, the survivor's value nudged down and up by
        the round's (shrinking) factor, other knobs held.  2·K candidates
        per survivor before clamping/dedup; never proposes the center
        point itself (it is already evaluated)."""
        out = {}
        for k in self.knobs:
            f = k.refine_factor(round_idx)
            v = values[k.field]
            for nv in (k.clamp(v / f), k.clamp(v * f)):
                if nv == v:
                    continue
                nvals = dict(values, **{k.field: nv})
                name, pol = self.make(nvals)
                out[name] = (pol, nvals)
        return out


# ---------------------------------------------------------------------------
# Built-in spaces
# ---------------------------------------------------------------------------

_LADDER = dict(sleep_state="fast_wake", deep_state="deep_sleep")

_BOUNDS = (0.005, 0.01, 0.02, 0.05)
_TPDTS = (1e-6, 1e-5, 1e-4, 1e-3)


def default_space() -> List[KindSpace]:
    """The full search space (52 candidates in 8 static groups).

    Coarse grids deliberately contain the PR-4 suite's fixed grid points
    (``fixed-fw-10us``, ``dual-10us-200us``, …) so the tuned winner can
    never fall behind the best fixed-grid policy on any scenario — the
    incumbent is always in round 0.  The predictive kinds (``pre``,
    ``predict``) join as their own static groups with the same guarantee:
    their knob grids include the degenerate points that collapse onto the
    reactive dual ladder.  The remaining kind, ``none``, is not a
    KindSpace: its parameterless single point IS the always-on baseline
    lane the tuner already rides in every pool (``frontier.BASELINE_NAME``,
    the guaranteed-feasible fallback) — listing it here would duplicate
    that lane and waste a knob-less survivor slot in halving rounds.
    """
    return [
        KindSpace("fixed-fw", Policy(kind="fixed", sleep_state="fast_wake"),
                  (Knob("t_pdt", _TPDTS, lo=0.0, hi=1.0),)),
        KindSpace("fixed-ds", Policy(kind="fixed", sleep_state="deep_sleep"),
                  (Knob("t_pdt", _TPDTS, lo=0.0, hi=1.0),)),
        KindSpace("pb", Policy(kind="perfbound", sleep_state="deep_sleep"),
                  (Knob("bound", _BOUNDS, step=4.0, lo=1e-4, hi=0.5),)),
        KindSpace("pbc", Policy(kind="perfbound_correct",
                                sleep_state="deep_sleep"),
                  (Knob("bound", _BOUNDS, step=4.0, lo=1e-4, hi=0.5),)),
        KindSpace("dual", Policy(kind="dual", **_LADDER),
                  (Knob("t_pdt", (1e-5, 1e-4), lo=0.0, hi=1.0),
                   Knob("t_dst", (5e-5, 2e-4, 1e-3), step=4.0,
                        lo=0.0, hi=1.0))),
        KindSpace("coal", Policy(kind="coalesce", t_pdt=1e-5, **_LADDER),
                  (Knob("t_dst", (2e-4,), step=4.0, lo=0.0, hi=1.0),
                   Knob("max_delay", (1e-5, 5e-5, 2e-4), step=4.0,
                        lo=0.0, hi=1e-2),
                   Knob("max_frames", (8, 16, 32), step=4.0, lo=1, hi=4096,
                        integer=True))),
        KindSpace("pbd", Policy(kind="perfbound_dual", **_LADDER),
                  (Knob("bound", _BOUNDS, step=4.0, lo=1e-4, hi=0.5),)),
        KindSpace("pre", Policy(kind="precoalesce", t_pdt=1e-5, **_LADDER),
                  (Knob("t_dst", (2e-4,), step=4.0, lo=0.0, hi=1.0),
                   Knob("hold_delay", (1e-5, 5e-5, 2e-4), step=4.0,
                        lo=0.0, hi=1e-2),
                   Knob("hold_frames", (8, 16, 32), step=4.0, lo=1, hi=4096,
                        integer=True))),
        KindSpace("predict", Policy(kind="predict", **_LADDER),
                  (Knob("t_pdt", (1e-5,), lo=0.0, hi=1.0),
                   Knob("t_dst", (5e-5, 2e-4), step=4.0, lo=0.0, hi=1.0),
                   Knob("forecast_weight", (0.5, 1.0), step=4.0,
                        lo=0.0, hi=1.0),
                   Knob("forecast_margin", (4.0, 16.0), step=4.0,
                        lo=0.125, hi=1024.0))),
    ]


def tiny_space() -> List[KindSpace]:
    """A compact space (12 candidates) for CI smoke and tests — same
    structure as ``default_space`` (every searched kind, every static
    group; ``none`` again rides as the implicit baseline), minimal
    lanes."""
    return [
        KindSpace("fixed-fw", Policy(kind="fixed", sleep_state="fast_wake"),
                  (Knob("t_pdt", (1e-5, 1e-4), lo=0.0, hi=1.0),)),
        KindSpace("fixed-ds", Policy(kind="fixed", sleep_state="deep_sleep"),
                  (Knob("t_pdt", (1e-4,), lo=0.0, hi=1.0),)),
        KindSpace("pb", Policy(kind="perfbound", sleep_state="deep_sleep"),
                  (Knob("bound", (0.01,), step=4.0, lo=1e-4, hi=0.5),)),
        KindSpace("pbc", Policy(kind="perfbound_correct",
                                sleep_state="deep_sleep"),
                  (Knob("bound", (0.01,), step=4.0, lo=1e-4, hi=0.5),)),
        KindSpace("dual", Policy(kind="dual", **_LADDER),
                  (Knob("t_pdt", (1e-5,), lo=0.0, hi=1.0),
                   Knob("t_dst", (5e-5, 2e-4), step=4.0, lo=0.0, hi=1.0))),
        KindSpace("coal", Policy(kind="coalesce", t_pdt=1e-5, t_dst=2e-4,
                                 max_frames=16, **_LADDER),
                  (Knob("max_delay", (5e-5,), step=4.0, lo=0.0, hi=1e-2),)),
        KindSpace("pbd", Policy(kind="perfbound_dual", **_LADDER),
                  (Knob("bound", (0.01, 0.05), step=4.0, lo=1e-4, hi=0.5),)),
        KindSpace("pre", Policy(kind="precoalesce", t_pdt=1e-5, t_dst=2e-4,
                                hold_frames=16, **_LADDER),
                  (Knob("hold_delay", (5e-5,), step=4.0, lo=0.0, hi=1e-2),)),
        KindSpace("predict", Policy(kind="predict", t_pdt=1e-5, t_dst=2e-4,
                                    forecast_margin=2.0, **_LADDER),
                  (Knob("forecast_weight", (0.5,), step=4.0,
                        lo=0.0, hi=1.0),)),
    ]


def space_candidates(space: List[KindSpace]):
    """Flatten a space's coarse grids: ``(policies, meta)`` where
    ``policies`` is the round-0 {name: Policy} grid and ``meta`` maps each
    name to its (KindSpace, knob assignment) for later refinement."""
    from repro.tuning.frontier import BASELINE_NAME
    policies: Dict[str, Policy] = {}
    meta: Dict[str, Tuple[KindSpace, Dict[str, float]]] = {}
    for ks in space:
        for name, (pol, values) in ks.coarse_grid().items():
            assert name not in policies, f"duplicate candidate {name!r}"
            assert name != BASELINE_NAME, \
                f"candidate label {name!r} would shadow the synthetic " \
                f"always-on baseline point (the guaranteed budget fallback)"
            assert static_key(pol) == static_key(ks.base), \
                f"{name!r}: knob changed static structure"
            policies[name] = pol
            meta[name] = (ks, values)
    return policies, meta
