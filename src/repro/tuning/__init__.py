"""Scenario-driven policy auto-tuning (DESIGN.md §7).

Public surface:

* :func:`~repro.tuning.tuner.tune_catalog` /
  :func:`~repro.tuning.tuner.tune_scenarios` — batched per-scenario
  frontier search under a degradation budget, riding the compiled
  (scenario × policy) grid pipeline;
* :class:`~repro.tuning.tuner.TuneReport` /
  :class:`~repro.tuning.tuner.ScenarioTuning` + ``format_report`` /
  ``report_rows`` — results and tables;
* :mod:`~repro.tuning.space` — the per-kind search space (coarse grids +
  successive-halving refinement): ``default_space`` / ``tiny_space`` /
  ``KindSpace`` / ``Knob``;
* :mod:`~repro.tuning.frontier` — the pure, property-tested selection
  math: ``TunePoint`` / ``pareto_frontier`` / ``budget_winner`` /
  ``select_survivors``.
"""
from repro.tuning.frontier import (BASELINE_NAME, TunePoint,  # noqa: F401
                                   budget_winner, dominates,
                                   pareto_frontier, rank_candidates,
                                   select_survivors)
from repro.tuning.space import (KindSpace, Knob,  # noqa: F401
                                default_space, space_candidates, tiny_space)
from repro.tuning.tuner import (OBJECTIVES, ScenarioTuning,  # noqa: F401
                                TuneReport, format_report, report_rows,
                                tune_catalog, tune_scenarios)

__all__ = [
    "BASELINE_NAME", "TunePoint", "budget_winner", "dominates",
    "pareto_frontier", "rank_candidates", "select_survivors",
    "KindSpace", "Knob", "default_space", "space_candidates", "tiny_space",
    "OBJECTIVES", "ScenarioTuning", "TuneReport", "format_report",
    "report_rows", "tune_catalog", "tune_scenarios",
]
