"""Scenario-driven policy auto-tuner: batched frontier search under a
degradation budget.

The paper's deliverable is not a single policy — it is the claim that
power-down must be *tuned to the workload* so energy saving comes with
"minimal or no performance penalty".  This module closes that loop over
the scenario catalog: given workloads and a degradation budget (percent
execution-time overhead vs each workload's own always-on baseline),
``tune_scenarios`` searches the whole policy space — all 9 kinds: eight
searched numeric parameter grids (``repro.tuning.space``, including the
predictive ``precoalesce``/``predict`` FSMs of DESIGN.md §8) plus the
ninth kind, ``none``, riding as the implicit always-on baseline lane of
every pool — and returns, per scenario, (a) the energy/degradation
Pareto frontier and (b) the minimum-energy policy that respects the
budget.

The search rides the compiled pipeline end to end — no Python-loop
replays (DESIGN.md §7):

* **round 0** seeds the coarse grid through
  ``scenarios.suite.evaluate_grid`` → ``sweep.sweep_scenarios``: traces
  stack by plan shape, each kind's grid is one batched lane group, the
  always-on baseline rides along;
* **halving rounds** keep the top ``keep`` candidates per scenario
  (budget-feasible by energy first, ``frontier.rank_candidates``),
  generate shrinking axis-wise neighbourhoods around the survivors
  (``space.KindSpace.refine``), and re-stack ONLY the surviving
  (scenario, static-group) cells through ``sweep.sweep_cells`` — one
  compiled replay per plan-shape × static-group per round, with lane
  unions shared across the stack.

Every decision (survivor ranking, candidate naming, tie-breaks) is
deterministic, so a warm rerun regenerates the exact same rounds and
compiles ZERO programs — pinned by the per-round compile counts in the
report and enforceable with ``compile_budget=0``
(``core.instrument.compile_guard``).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.eee import PowerModel
from repro.core.instrument import compile_guard, count_compiles
from repro.core.simulator import SimResult, relative_rows
from repro.core.sweep import sweep_cells
from repro.scenarios.spec import build_trace
from repro.scenarios.suite import evaluate_grid, resolve
from repro.tuning.frontier import (BASELINE_NAME, TunePoint, budget_winner,
                                   pareto_frontier, select_survivors)
from repro.tuning.space import default_space, space_candidates

# SimResult fields that make sense as a minimization objective
OBJECTIVES = ("link_energy", "total_energy")


@dataclass
class ScenarioTuning:
    """One scenario's search outcome."""
    scenario: str
    budget_pct: float
    objective: str
    baseline: SimResult
    points: Dict[str, TunePoint]         # every evaluated candidate + baseline
    frontier: List[TunePoint] = field(default_factory=list)
    winner: Optional[TunePoint] = None   # never None after finalize()

    def finalize(self) -> "ScenarioTuning":
        self.frontier = pareto_frontier(self.points.values())
        self.winner = budget_winner(self.points.values(), self.budget_pct)
        assert self.winner is not None, \
            "baseline point missing: the budget winner must always exist"
        return self


@dataclass
class TuneReport:
    """Catalog-wide tuning outcome + per-round search accounting."""
    budget_pct: float
    objective: str
    scenarios: Dict[str, ScenarioTuning]
    rounds: List[dict]                   # {round, scenarios, cells, compiles}

    @property
    def round_compiles(self) -> List[int]:
        return [r["compiles"] for r in self.rounds]

    def winners(self) -> Dict[str, TunePoint]:
        return {sc: t.winner for sc, t in self.scenarios.items()}


def _points_from(results: Dict[str, SimResult], base: SimResult,
                 policies: Dict, objective: str, round_idx: int
                 ) -> Dict[str, TunePoint]:
    """Lower a scenario's round results to objective-space points; the
    table row (§4 protocol percentages) rides along for reporting."""
    rows = relative_rows(base, results, BASELINE_NAME)
    out = {}
    for name, res in results.items():
        out[name] = TunePoint(
            name=name, degradation=rows[name]["exec_overhead_pct"],
            energy=float(getattr(res, objective)), round=round_idx,
            policy=policies[name], row=rows[name])
    return out


def _baseline_point(base: SimResult, objective: str) -> TunePoint:
    row = relative_rows(base, {}, BASELINE_NAME)[BASELINE_NAME]
    return TunePoint(name=BASELINE_NAME, degradation=0.0,
                     energy=float(getattr(base, objective)), round=0,
                     policy=None, row=row)


def tune_scenarios(topo, scenarios=None, *, budget_pct: float = 1.0,
                   rounds: int = 3, space=None, keep: int = 4,
                   n_nodes: Optional[int] = None,
                   max_group: Optional[int] = None,
                   objective: str = "link_energy",
                   pm: Optional[PowerModel] = None,
                   compile_budget: Optional[int] = None,
                   packing: str = "pow2") -> TuneReport:
    """Search the policy space for every scenario, batched.

    ``scenarios`` accepts catalog names / Scenario specs (default: the
    whole catalog, as in ``scenarios.run_suite``); ``budget_pct`` is the
    degradation budget (max execution-time overhead vs each scenario's own
    baseline, in percent); ``rounds`` counts the coarse round plus
    successive-halving refinements (3 → coarse + 2 refinements); ``keep``
    is the per-scenario survivor count each halving round refines around;
    ``objective`` is the SimResult energy field to minimize.

    ``compile_budget`` (when not None) runs the WHOLE search under
    ``instrument.compile_guard`` — pass 0 on a warm rerun to hard-assert
    that every round reuses the cold run's programs.

    ``packing`` passes through to ``sweep_cells`` (``"ragged"`` repacks
    stacked plans into size-class segments — same results, less padding).
    The search goes multi-device transparently when a mesh is active
    (``repro.distributed.shard_sweep.use_mesh``).

    Returns a :class:`TuneReport`; per-round compile counts land in
    ``report.rounds`` so callers can pin cache behaviour.
    """
    pm = pm or PowerModel()
    assert objective in OBJECTIVES, \
        f"objective {objective!r} not in {OBJECTIVES}"
    assert rounds >= 1 and keep >= 1 and budget_pct >= 0.0
    space = space if space is not None else default_space()
    specs = resolve(scenarios, n_nodes)
    traces = {name: build_trace(spec, topo) for name, spec in specs.items()}
    grid0, meta = space_candidates(space)

    guard = (compile_guard("tune_scenarios", compile_budget)
             if compile_budget is not None else contextlib.nullcontext())
    round_log: List[dict] = []
    with guard:
        # ---- round 0: the coarse grid, every scenario ---------------------
        with count_compiles() as cc:
            base, res0 = evaluate_grid(traces, topo, grid0, pm,
                                       max_group=max_group,
                                       packing=packing)
        tunings = {}
        for sc in traces:
            points = {BASELINE_NAME: _baseline_point(base[sc], objective)}
            points.update(_points_from(res0[sc], base[sc], grid0,
                                       objective, 0))
            tunings[sc] = ScenarioTuning(sc, budget_pct, objective,
                                         base[sc], points)
        round_log.append({"round": 0, "scenarios": len(traces),
                          "cells": len(traces) * (len(grid0) + 1),
                          "compiles": cc.count})

        # ---- successive-halving refinement rounds -------------------------
        for r in range(1, rounds):
            cells: Dict[str, Dict] = {}
            for sc, tuning in tunings.items():
                survivors = select_survivors(tuning.points.values(),
                                             budget_pct, keep)
                fresh = {}
                for s in survivors:
                    ks, values = meta[s.name]
                    for name, (pol, vals) in ks.refine(values, r).items():
                        meta.setdefault(name, (ks, vals))
                        if name not in tuning.points:
                            fresh[name] = pol
                if fresh:
                    cells[sc] = fresh
            if not cells:
                break                    # every neighbourhood converged
            with count_compiles() as cc:
                res_r = sweep_cells({sc: traces[sc] for sc in cells}, topo,
                                    cells, pm, max_group=max_group,
                                    packing=packing)
            for sc, results in res_r.items():
                tunings[sc].points.update(_points_from(
                    results, base[sc], cells[sc], objective, r))
            round_log.append({"round": r, "scenarios": len(cells),
                              "cells": sum(map(len, cells.values())),
                              "compiles": cc.count})

    for tuning in tunings.values():
        tuning.finalize()
    return TuneReport(budget_pct, objective, tunings, round_log)


def tune_catalog(topo, **kw) -> TuneReport:
    """``tune_scenarios`` over the full built-in catalog (the repo's
    "tell me your workload, I'll hand you the knob settings" entry point —
    see also ``launch.power_advisor.advise_scenario`` for the one-scenario
    recommendation wrapper)."""
    return tune_scenarios(topo, None, **kw)


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

CSV_FIELDS = ("scenario", "policy", "round", "degradation_pct",
              "energy_J", "energy_saved_pct", "link_energy_saved_pct",
              "on_frontier", "is_winner")


def report_rows(report: TuneReport):
    """Flatten a report's frontier + winner sets to CSV-ready dict rows."""
    for sc, tuning in report.scenarios.items():
        on_frontier = {p.name for p in tuning.frontier}
        names = sorted(on_frontier | {tuning.winner.name},
                       key=lambda n: tuning.points[n]._key())
        for name in names:
            p = tuning.points[name]
            yield {"scenario": sc, "policy": name, "round": p.round,
                   "degradation_pct": p.degradation, "energy_J": p.energy,
                   "energy_saved_pct": p.row["energy_saved_pct"],
                   "link_energy_saved_pct":
                       p.row["link_energy_saved_pct"],
                   "on_frontier": name in on_frontier,
                   "is_winner": name == tuning.winner.name}


def format_report(report: TuneReport) -> str:
    """Human-readable per-scenario frontier/winner tables."""
    lines = [f"budget <= {report.budget_pct:g}% exec overhead, "
             f"objective = min {report.objective}"]
    for sc, tuning in report.scenarios.items():
        w = tuning.winner
        lines.append(f"== {sc}")
        lines.append(f"   winner: {w.name}  "
                     f"(overhead {w.degradation:.3f}%, "
                     f"link saved {w.row['link_energy_saved_pct']:.2f}%, "
                     f"total saved {w.row['energy_saved_pct']:.2f}%)")
        lines.append(f"   {'frontier policy':<34} {'overhead%':>10} "
                     f"{'link_saved%':>12} {'saved%':>8} {'round':>6}")
        for p in tuning.frontier:
            lines.append(f"   {p.name:<34} {p.degradation:>10.3f} "
                         f"{p.row['link_energy_saved_pct']:>12.2f} "
                         f"{p.row['energy_saved_pct']:>8.2f} "
                         f"{p.round:>6d}")
    return "\n".join(lines)
