"""Pure frontier/selection math for the policy auto-tuner.

Everything in this module is plain-Python over :class:`TunePoint` values —
no JAX, no simulation — so the tuner's decision logic (Pareto dominance,
budget-constrained winner selection, successive-halving survivor ranking)
is directly property-testable (``tests/test_tuning.py`` drives it with
hypothesis): the frontier is non-dominated and sorted, the winner never
violates the budget, and adding points never makes the winner worse.

Conventions: ``degradation`` is the §4 execution-time overhead in percent
vs the scenario's own always-on baseline (lower is better, 0 for the
baseline itself); ``energy`` is the objective energy in joules (lower is
better).  Ties break deterministically by (values, name) so a warm tuner
rerun reproduces the cold run's decisions bit for bit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, List, Optional

BASELINE_NAME = "baseline"


@dataclass(frozen=True)
class TunePoint:
    """One evaluated (policy, scenario) cell in objective space."""
    name: str
    degradation: float           # exec overhead % vs the scenario baseline
    energy: float                # objective energy (J), lower is better
    round: int = 0               # search round that produced the point
    policy: object = None        # the Policy (None for synthetic test points)
    row: dict = field(default=None, compare=False, repr=False)  # full table row

    def _key(self):
        return (self.degradation, self.energy, self.name)


def dominates(a: TunePoint, b: TunePoint) -> bool:
    """True when ``a`` is at least as good on both axes and better on one."""
    return (a.degradation <= b.degradation and a.energy <= b.energy
            and (a.degradation < b.degradation or a.energy < b.energy))


def pareto_frontier(points: Iterable[TunePoint]) -> List[TunePoint]:
    """Non-dominated subset, sorted by ascending degradation.

    One linear scan over the (degradation, energy, name)-sorted points
    keeps every point that strictly improves the best energy seen so far;
    of coincident (degradation, energy) pairs the lexicographically first
    name survives.  The result's energies are strictly decreasing, so the
    frontier reads as "each extra unit of degradation buys this much
    energy".
    """
    out: List[TunePoint] = []
    best = float("inf")
    for p in sorted(points, key=TunePoint._key):
        if p.energy < best:
            out.append(p)
            best = p.energy
    return out


def budget_winner(points: Iterable[TunePoint],
                  budget: float) -> Optional[TunePoint]:
    """Lowest-energy point with degradation <= ``budget`` (then lowest
    degradation, then name, as deterministic tie-breaks).  ``None`` when
    nothing is feasible — callers that seed the always-on baseline point
    (degradation 0) always get a winner for any budget >= 0.
    """
    feasible = [p for p in points if p.degradation <= budget]
    if not feasible:
        return None
    return min(feasible, key=lambda p: (p.energy, p.degradation, p.name))


def rank_candidates(points: Iterable[TunePoint],
                    budget: float) -> List[TunePoint]:
    """Successive-halving ranking: budget-feasible points first (by energy,
    the winner objective), then infeasible ones by how close they are to
    feasibility (degradation, then energy) — an infeasible region is still
    worth refining toward the boundary when nothing else saves more."""
    feasible, infeasible = [], []
    for p in points:
        (feasible if p.degradation <= budget else infeasible).append(p)
    feasible.sort(key=lambda p: (p.energy, p.degradation, p.name))
    infeasible.sort(key=lambda p: (p.degradation, p.energy, p.name))
    return feasible + infeasible


def select_survivors(points: Iterable[TunePoint], budget: float,
                     keep: int) -> List[TunePoint]:
    """The top ``keep`` candidates a halving round refines around.

    The synthetic baseline point is never a survivor — it has no knobs to
    refine — but it stays in the pool every round, so the winner can
    always fall back to "don't power manage" under an infeasibly tight
    budget.
    """
    ranked = [p for p in rank_candidates(points, budget)
              if p.name != BASELINE_NAME]
    return ranked[:max(keep, 0)]
