"""int8 gradient compression with error feedback (cross-pod DP traffic).

At 512+ chips the cross-pod gradient all-reduce is the collective-roofline
term that grows with pod count, and the slowest hop (inter-pod DCN/ICI).
Compressing the cross-pod leg 4x (f32 -> int8 + per-block scales) cuts that
wire time ~4x at a quantization error that error feedback (EF, Seide et al.;
1-bit Adam lineage) removes asymptotically: the residual of every quantize
is added back before the next one.

Implementation notes
--------------------
* Quantization is per-block (``block`` values share one f32 scale) —
  symmetric int8, scale = max|x|/127.  Flat layout so any pytree leaf maps
  onto it after ravel.
* ``compressed_psum``: inside ``shard_map`` the quantized payload is summed
  with ``lax.psum`` over the 'pod' axis.  int8 would overflow in the sum, so
  the wire dtype widens only after the (local) scale multiply — we psum the
  *dequantized* int8 payload; what travels is the int8-rounded values, i.e.
  the all-reduce input entropy matches int8+scales.  On hardware with int8
  collectives the same wrapper lowers to a true 4x-smaller transfer; the
  error-feedback math (what the paper's technique cares about: *how much
  traffic and when*) is identical.
* The train-step integration quantizes only the *cross-pod* leg: intra-pod
  reduction in full precision (cheap links), inter-pod compressed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def quantize_int8(x, block: int = 2048):
    """x: any-shape float -> (q int8 (n_blocks, block), scales f32, meta)."""
    flat = x.astype(jnp.float32).ravel()
    n = flat.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(nb, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-30)),
                 -127, 127).astype(jnp.int8)
    return q, scale, (x.shape, n)


def dequantize_int8(q, scale, meta):
    shape, n = meta
    flat = (q.astype(jnp.float32) * scale).ravel()[:n]
    return flat.reshape(shape)


def ef_quantize(x, err, block: int = 2048):
    """Error-feedback quantize: returns (q, scale, meta, new_err)."""
    corrected = x.astype(jnp.float32) + err
    q, scale, meta = quantize_int8(corrected, block)
    deq = dequantize_int8(q, scale, meta)
    return q, scale, meta, corrected - deq


def init_error_feedback(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, err_tree, block: int = 2048):
    """Quantize every leaf with EF.  Returns (payload tree, new_err tree).
    payload leaves are (q, scale, meta) triples (meta is static)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    qs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, m, ne = ef_quantize(g, e, block)
        qs.append((q, s, m))
        errs.append(ne)
    return (jax.tree.unflatten(tdef, [q for q in qs]),
            jax.tree.unflatten(tdef, errs))


def decompress_tree(payload):
    return jax.tree.map(lambda t: dequantize_int8(*t), payload,
                        is_leaf=lambda t: isinstance(t, tuple))


def compressed_mean(grads, err_tree, axis_name: str, block: int = 2048):
    """EF-int8 mean over ``axis_name`` (call inside shard_map/pmap).

    Returns (mean_grads, new_err).  The wire payload per leaf is the int8
    quantization of (grad + err); the psum itself runs on the dequantized
    values (see module docstring for the hardware note).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, s, m, ne = ef_quantize(g, e, block)
        deq = dequantize_int8(q, s, m)
        return jax.lax.psum(deq, axis_name) / n, ne

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def compression_ratio(params, block: int = 2048) -> float:
    """Wire bytes (int8 + scales) / f32 bytes, over a param pytree."""
    tot_f32, tot_wire = 0, 0
    for p in jax.tree.leaves(params):
        n = int(jnp.size(p))
        nb = -(-n // block)
        tot_f32 += 4 * n
        tot_wire += n + 4 * nb
    return tot_wire / max(tot_f32, 1)
