"""Train step + loss; builds the jitted, sharded step for any arch/shape."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

AUX_LOSS_WEIGHT = 0.01


def cross_entropy(logits, labels, vocab_size):
    """logits: (B,S,Vp) any float dtype; labels: (B,S) int (-1 = ignore)."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    lab = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def make_loss_fn(cfg):
    def loss_fn(params, batch):
        out = M.forward(params, batch, cfg, mode="train")
        ce = cross_entropy(out["logits"], batch["labels"], cfg.vocab_size)
        loss = ce + AUX_LOSS_WEIGHT * out["aux_loss"]
        return loss, {"ce": ce, "aux": out["aux_loss"]}
    return loss_fn


def make_train_step(cfg, opt_cfg: AdamWConfig = AdamWConfig(),
                    grad_accum: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {'params', 'opt'}.  With grad_accum > 1 the batch's leading dim
    is split into microbatches scanned sequentially (activation memory /
    accum lower, same math).
    """
    loss_fn = make_loss_fn(cfg)

    def grads_of(params, batch):
        (loss, met), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, met, grads

    def train_step(state, batch):
        params = state["params"]
        if grad_accum > 1:
            def micro(carry, mb):
                acc, lsum = carry
                loss, _, g = grads_of(params, mb)
                return (jax.tree.map(jnp.add, acc, g), lsum + loss), None
            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            met = {}
        else:
            loss, met, grads = grads_of(params, batch)
        new_params, new_opt, gnorm = adamw_update(opt_cfg, params, grads,
                                                  state["opt"])
        metrics = {"loss": loss, "grad_norm": gnorm, **met}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(cfg, key):
    params = M.init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(params)}


def abstract_train_state(cfg):
    return jax.eval_shape(partial(init_train_state, cfg),
                          jax.random.PRNGKey(0))
