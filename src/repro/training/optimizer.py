"""AdamW with global-norm clipping — plain pytree implementation, sharded
states inherit the parameter shardings."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params):
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                            + cfg.weight_decay * p32)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
