"""Topology-independent checkpointing with async writes and elastic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        MANIFEST.json      # tree structure, per-leaf shape/dtype, metadata
        <leaf-path>.npy    # one file per pytree leaf (full, unsharded array)
        COMMIT             # written last — its presence marks a valid ckpt

Design points mirroring what a 1000-node deployment needs:

* **Topology independence** — leaves are stored as full logical arrays plus
  a manifest, so a job saved on a (pod=2, data=16, model=16) mesh restores
  onto any other device count: ``restore_checkpoint(..., shardings=...)``
  simply ``device_put``s with the *new* shardings (elastic restart).  On a
  real multi-host fleet the same manifest drives shard-per-host writes; the
  single-process implementation is the degenerate case of that protocol.
* **Atomicity** — writes land in ``<name>.tmp`` and are renamed after the
  COMMIT marker is written; interrupted saves are invisible to ``latest``.
* **Async saves** — ``save_async`` snapshots to host memory (device_get)
  on the caller thread (cheap, contiguous D2H) and runs the file I/O on a
  background thread, overlapping with the next training steps.
* **Retention** — ``keep`` newest checkpoints are retained; older ones are
  garbage-collected after each successful commit.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Optional

import jax
import numpy as np


def _leaf_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("__".join(parts) or "leaf", leaf))
    return out


def save_checkpoint(directory, state, step: int, metadata: Optional[dict] = None):
    """Blocking save.  ``state`` is any pytree of arrays."""
    directory = Path(directory)
    final = directory / f"step_{step:09d}"
    tmp = directory / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    host_state = jax.device_get(state)
    named = _leaf_paths(host_state)
    manifest = {
        "step": step,
        "time": time.time(),
        "metadata": metadata or {},
        "leaves": [],
        "treedef": None,
    }
    for name, leaf in named:
        arr = np.asarray(leaf)
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    # treedef as a reproducible string (validated on restore)
    manifest["treedef"] = str(jax.tree_util.tree_structure(host_state))
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    (tmp / "COMMIT").write_text(str(step))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def _valid_steps(directory):
    directory = Path(directory)
    if not directory.exists():
        return []
    steps = []
    for p in directory.iterdir():
        if p.name.startswith("step_") and not p.name.endswith(".tmp") \
                and (p / "COMMIT").exists():
            steps.append(int(p.name[5:]))
    return sorted(steps)


def latest_step(directory) -> Optional[int]:
    steps = _valid_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory, template, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    ``NamedSharding`` — enables elastic restore onto a different mesh.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = directory / f"step_{step:09d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())

    named = _leaf_paths(template)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    flat = []
    for name, leaf in named:
        if name not in by_name:
            raise KeyError(f"checkpoint {d} missing leaf {name!r}")
        arr = np.load(d / f"{name}.npy")
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {name!r}: checkpoint shape {arr.shape} != "
                f"template {want_shape}")
        flat.append(arr.astype(leaf.dtype))
    treedef = jax.tree_util.tree_structure(template)
    state = jax.tree_util.tree_unflatten(treedef, flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state, step, manifest["metadata"]


class CheckpointManager:
    """Retention + async-save orchestration around save/restore."""

    def __init__(self, directory, keep: int = 3, save_interval_steps: int = 0):
        self.directory = Path(directory)
        self.keep = keep
        self.save_interval_steps = save_interval_steps
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- policy --------------------------------------------------------------
    def should_save(self, step: int) -> bool:
        return (self.save_interval_steps > 0
                and step % self.save_interval_steps == 0)

    # -- sync ------------------------------------------------------------------
    def save(self, state, step: int, metadata: Optional[dict] = None):
        self.wait()  # only one outstanding write
        path = save_checkpoint(self.directory, state, step, metadata)
        self._gc()
        return path

    # -- async ----------------------------------------------------------------
    def save_async(self, state, step: int, metadata: Optional[dict] = None):
        """Snapshot now, write in the background.  Raises any prior error."""
        self.wait()
        host_state = jax.device_get(state)  # snapshot before training mutates

        def work():
            try:
                save_checkpoint(self.directory, host_state, step, metadata)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # -- restore ---------------------------------------------------------------
    def restore(self, template, step=None, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, template, step, shardings)

    def latest_step(self):
        return latest_step(self.directory)

    def _gc(self):
        steps = _valid_steps(self.directory)
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.directory / f"step_{s:09d}", ignore_errors=True)
