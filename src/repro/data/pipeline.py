"""Synthetic LM data pipeline: sharded, deterministic, prefetched.

The stream is a first-order Markov chain over the vocabulary with a sparse
transition structure, so a model CAN learn it (loss decreases measurably
within a few hundred steps — the e2e training example asserts this), yet
generation is pure numpy and fully deterministic given (seed, shard, step).

Sharding contract: ``SyntheticLM(..., shard=i, num_shards=n)`` yields the
i-th slice of every global batch, so n data-parallel hosts construct the
identical global batch independently — the layout a multi-pod input pipeline
needs (no host broadcast).  Prefetching runs on a daemon thread with a small
bounded queue.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    """Deterministic Markov-chain token stream.

    Each step's batch is generated from ``hash(seed, step, shard)`` so
    restarting from a checkpoint at step k reproduces the exact remaining
    stream (checkpoint/restart invariance, tested).
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, shard: int = 0, num_shards: int = 1,
                 branch: int = 4):
        assert global_batch % num_shards == 0
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.batch = global_batch // num_shards
        self.seed = seed
        self.shard = shard
        self.num_shards = num_shards
        # sparse transition table: each token can be followed by ``branch``
        # successors (uniform) — entropy log2(branch) bits/token, learnable.
        rng = np.random.default_rng(seed)
        self.next_tok = rng.integers(
            0, vocab_size, size=(vocab_size, branch), dtype=np.int32)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        B, S = self.batch, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, B)
        choices = rng.integers(0, self.next_tok.shape[1], (B, S))
        for t in range(S):
            toks[:, t + 1] = self.next_tok[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class _Prefetcher:
    """Bounded-queue background prefetch over ``source.batch_at(step)``."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        return self.q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()


def make_pipeline(cfg, seq_len: int, global_batch: int, *, seed: int = 0,
                  shard: int = 0, num_shards: int = 1, start_step: int = 0,
                  prefetch: int = 2):
    """Returns an iterator of (step, {'tokens','labels'}) numpy batches."""
    src = SyntheticLM(cfg.vocab_size, seq_len, global_batch, seed=seed,
                      shard=shard, num_shards=num_shards)
    if prefetch:
        return _Prefetcher(src, start_step=start_step, depth=prefetch)
    def _gen():
        step = start_step
        while True:
            yield step, src.batch_at(step)
            step += 1
    return _gen()
