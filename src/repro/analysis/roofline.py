"""Roofline analysis from dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled module (no hardware needed):

    compute    = HLO_FLOPs   / (chips x peak_FLOPs)
    memory     = HLO_bytes   / (chips x HBM_bw)
    collective = wire_bytes  / (chips x links x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-module,
so we divide by chip count); wire_bytes is the per-device ring-equivalent
byte count from the HLO collective census (already per device — the census
reads the per-device SPMD module).

Hardware constants (TPU v5e class, per the brief): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI with 2 usable ICI links per chip on a
2D-torus axis mapping (data, model) -> torus dims.

Also reported: MODEL_FLOPS = 6*N*D (dense; N_active for MoE) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste), the
dominant term, and a one-line lever on the dominant term.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s per ICI link
LINKS_PER_CHIP = 2         # usable concurrent ICI links (ring collectives
                           # on one mesh axis use tx+rx of one link pair)

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    bound_s: float = 0.0
    dominant: str = ""
    fraction: float = 0.0      # dominant / total  (how skewed)

    def __post_init__(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        self.bound_s = max(terms.values())
        tot = sum(terms.values())
        self.fraction = self.bound_s / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Best-case MFU if the job ran exactly at the max-term bound:
        useful model FLOPs / (chips x peak x bound time)."""
        if self.bound_s <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * self.bound_s)

    def lever(self) -> str:
        if self.dominant == "collective":
            return ("cut wire bytes: reshard to turn per-layer ARs into "
                    "RS+AG, overlap via latency-hiding scheduler")
        if self.dominant == "memory":
            return ("cut HBM traffic: less remat recompute, larger fused "
                    "blocks, bf16 residuals/caches")
        return ("compute-bound (good): raise MFU via MXU-aligned tiles "
                "and fewer non-matmul FLOPs")


def cell_roofline(rec: dict) -> Roofline:
    chips = rec["n_devices"]
    # prefer the trip-count-corrected module cost (repro.analysis.hlo.
    # module_cost); XLA's cost_analysis counts while bodies once and
    # undercounts scanned-layer models by ~num_layers
    cost = rec.get("hlo_cost") or {
        "flops": rec["cost"].get("flops", 0.0),
        "bytes": rec["cost"].get("bytes accessed", 0.0)}
    flops = float(cost["flops"])
    byts = float(cost["bytes"])
    wire = float(rec["collectives"].get("wire_bytes", 0.0))
    # all values are per device (the census/module cost read the
    # per-device SPMD program).  MODEL_FLOPS: 6*N*D for training
    # (fwd+bwd), 2*N*D for inference kinds (fwd only).
    factor = 6 if rec.get("kind") == "train" else 2
    model_flops = factor * rec["active_param_count"] * rec["tokens"]
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = wire / (LINKS_PER_CHIP * LINK_BW)
    hlo_total = flops * chips
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        model_flops=model_flops, hlo_flops=hlo_total,
        useful_ratio=model_flops / hlo_total if hlo_total else 0.0)


def load_all(dryrun_dir=DRYRUN_DIR, mesh: str | None = "16x16"):
    out = []
    for p in sorted(Path(dryrun_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        out.append(cell_roofline(rec))
    return out


def table(rows, fmt="md"):
    hdr = ["arch", "shape", "chips", "compute_s", "memory_s", "collective_s",
           "dominant", "MODEL/HLO", "roofline_frac", "lever"]
    lines = []
    if fmt == "md":
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for r in sorted(rows, key=lambda r: (r.arch, r.shape)):
        cells = [r.arch, r.shape, str(r.chips),
                 f"{r.compute_s:.4g}", f"{r.memory_s:.4g}",
                 f"{r.collective_s:.4g}", r.dominant,
                 f"{r.useful_ratio:.3f}", f"{r.roofline_fraction:.3f}",
                 r.lever().split(":")[0]]
        lines.append("| " + " | ".join(cells) + " |" if fmt == "md"
                     else ",".join(cells))
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--fmt", choices=["md", "csv"], default="md")
    ap.add_argument("--dir", default=str(DRYRUN_DIR))
    args = ap.parse_args()
    rows = load_all(args.dir, args.mesh)
    print(table(rows, args.fmt))
    by_dom = {}
    for r in rows:
        by_dom.setdefault(r.dominant, []).append(r)
    print(f"\n# {len(rows)} cells; dominant-term split: "
          + ", ".join(f"{k}={len(v)}" for k, v in sorted(by_dom.items())))


if __name__ == "__main__":
    main()
