"""Post-SPMD HLO text analysis: collective-op byte census.

``cost_analysis`` does not report collective traffic, so we parse the
compiled module: every all-reduce / all-gather / reduce-scatter / all-to-all
/ collective-permute op is summed (operand bytes, per device), multiplying
ops inside ``while`` bodies (scanned layers, KV loops) by the loop trip
count (XLA's ``known_trip_count`` backend_config, with a constant-in-
condition fallback).

Format notes (XLA CPU/TPU post-optimization HLO):
  * computation headers sit at column 0: ``%name (args...) -> type {`` —
    args may contain nested parentheses (tuple params), so the header regex
    only consumes up to the first ``(``;
  * async pairs ``<op>-start`` / ``<op>-done``: the start op's result is a
    tuple holding (operand alias, result, ...); we take the largest element
    as the transfer payload and skip the ``-done`` line;
  * replica_groups come as explicit lists ``{{0,1},{2,3}}`` or iota form
    ``[G,S]<=[N]...`` (G groups of S participants).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](T\([\d,]+\))?")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n"\s*:\s*"(\d+)"')
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")


def _shapes_in(type_str: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


def _result_bytes(type_str: str, started: bool) -> int:
    """Payload bytes of a collective's result type.

    Plain ops: sum every array in the (possibly tuple) type.  ``-start``
    ops return (operand alias, result, [scratch]) — take the largest array
    to avoid double-counting the aliased operand.
    """
    shapes = _shapes_in(type_str)
    if not shapes:
        return 0
    if started:
        return max(shapes)
    return sum(shapes) if len(shapes) == 1 else max(shapes)


def _split_computations(text: str) -> dict:
    comps, name, buf = {}, None, []
    for line in text.splitlines():
        if name is None:
            if not line.startswith((" ", "\t")) and line.rstrip().endswith("{"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    name = m.group(1)
                    buf = []
            continue
        if line.startswith("}"):
            comps[name] = buf
            name = None
            continue
        buf.append(line.strip())
    return comps


def _call_graph(comps):
    """(trip, caller): while-loop trip counts and callee->caller edges
    (fusion calls, reductions, while bodies/conds, conditional branches)."""
    trip, caller = {}, {}
    for name, lines in comps.items():
        for ln in lines:
            m = _WHILE_RE.search(ln)
            if m:
                cond, body = m.group(1), m.group(2)
                tm = _TRIP_RE.search(ln)
                if tm:
                    t = int(tm.group(1))
                else:  # fallback: largest s32 constant in the condition
                    consts = []
                    for cl in comps.get(cond, []):
                        consts += [int(x) for x in _CONST_RE.findall(cl)]
                    t = max(consts) if consts else 1
                trip[body] = t
                caller[body] = name
                caller[cond] = name
            for cal in _CALLS_RE.findall(ln):
                caller.setdefault(cal, name)
            # conditional branches run (at most once) per parent visit
            for bm in _BRANCHES_RE.finditer(ln):
                names = bm.group(1) or ""
                for part in (re.findall(r"%?([\w\.\-]+)", names)
                             + [bm.group(2), bm.group(3)]):
                    if part:
                        caller.setdefault(part, name)
    return trip, caller


def _mult(comp, trip, caller, seen=()):
    if comp in seen:
        return 1
    m = trip.get(comp, 1)
    c = caller.get(comp)
    return m * (_mult(c, trip, caller, seen + (comp,)) if c else 1)


def collective_census(hlo_text: str) -> dict:
    """Returns {'per_op': {op: bytes}, 'total_bytes': float,
    'wire_bytes': float, 'n_ops': int, 'while_trip_counts': {...}}.

    ``total_bytes`` sums logical operand bytes (x trip count), per device.
    ``wire_bytes`` applies ring-algorithm factors per op kind and group
    size n: all-reduce 2(n-1)/n, all-gather/reduce-scatter/all-to-all
    (n-1)/n, collective-permute 1.
    """
    comps = _split_computations(hlo_text)
    trip, caller = _call_graph(comps)

    def multiplier(comp):
        return _mult(comp, trip, caller)

    per_op = defaultdict(float)
    per_op_count = defaultdict(int)
    per_axis = defaultdict(float)
    wire_axis = defaultdict(float)
    wire = 0.0
    n_ops = 0
    for name, lines in comps.items():
        mult = multiplier(name)
        for ln in lines:
            m = _COLL_RE.search(ln)
            if not m:
                continue
            type_str, op, started = m.group(1), m.group(2), bool(m.group(3))
            res_bytes = _result_bytes(type_str, started)
            # group size + axis classification: groups of CONTIGUOUS device
            # ids run along the innermost mesh axis ('model' -> TP/EP/SP);
            # strided or permuted groups cross it ('data'/'pod' -> DP).
            g = _GROUPS_RE.search(ln)
            axis = "dp"
            if g:
                members = [int(x) for x in g.group(1).split(",")]
                n = len(members)
                if members == list(range(members[0], members[0] + n)):
                    axis = "tp"
            else:
                g2 = _GROUPS_IOTA_RE.search(ln)
                if g2:
                    n = int(g2.group(2))
                    axis = "dp" if g2.group(4) else "tp"  # T(..) = strided
                else:
                    n = 1
            n = max(n, 1)
            if n == 1:
                axis = "local"
            if op == "all-gather":
                operand = res_bytes / n
                w = res_bytes * (n - 1) / n
            elif op == "reduce-scatter":
                operand = res_bytes * n
                w = operand * (n - 1) / n
            elif op == "all-reduce":
                operand = res_bytes
                w = 2 * res_bytes * (n - 1) / n
            elif op == "all-to-all":
                operand = res_bytes
                w = res_bytes * (n - 1) / n
            else:  # collective-permute
                operand = res_bytes
                w = res_bytes
            per_op[op] += operand * mult
            per_op_count[op] += mult
            per_axis[axis] += operand * mult
            wire += w * mult
            wire_axis[axis] += w * mult
            n_ops += 1
    return {
        "per_op": {k: float(v) for k, v in per_op.items()},
        "per_op_count": dict(per_op_count),
        "per_axis": {k: float(v) for k, v in per_axis.items()},
        "wire_axis": {k: float(v) for k, v in wire_axis.items()},
        "total_bytes": float(sum(per_op.values())),
        "wire_bytes": float(wire),
        "n_ops": n_ops,
        "while_trip_counts": dict(trip),
    }


# ---------------------------------------------------------------------------
# Module cost (FLOPs / HBM bytes) with loop-trip multipliers
# ---------------------------------------------------------------------------
#
# ``compiled.cost_analysis()`` counts every computation ONCE — a scanned
# 60-layer transformer reports ~1 layer of FLOPs.  We re-derive both terms
# from the scheduled module text, multiplying by while-loop trip counts:
#
#   * FLOPs: every ``dot`` op contributes 2 x numel(result) x K (K = the
#     product of its lhs contracting-dim sizes, looked up from the operand's
#     defining instruction).  Dots inside fusions are found by walking
#     fusion computations with their caller's multiplier.
#   * HBM bytes: post-fusion HLO is exactly HBM-materialization
#     granularity — each scheduled instruction reads its operands and
#     writes its result once.  We sum operand+result bytes over scheduled
#     (non-fusion-internal) instructions, skipping aliasing/no-op kinds.

_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
                       r"(\([^=]*?\)|\S+)\s+([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(
    r"(?:branch_computations=\{([^}]*)\}"
    r"|true_computation=%?([\w\.\-]+)|false_computation=%?([\w\.\-]+))")
_DIMS_RE = re.compile(r"\w+\[([\d,]*)\]")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "get-dimension-size", "opt-barrier",
               # control-flow wrappers alias their carry, they don't move it
               "while", "conditional", "call"}


def _first_dims(type_str: str):
    m = _DIMS_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(1).split(",")] if m.group(1) else []


def module_cost(hlo_text: str) -> dict:
    """Returns {'flops': float, 'bytes': float, 'dot_flops_by_comp': {...}}
    per device, with while-trip multipliers applied."""
    comps = _split_computations(hlo_text)
    trip, caller = _call_graph(comps)

    fusion_comps = set()
    for name, lines in comps.items():
        for ln in lines:
            if " fusion(" in ln:
                for cal in _CALLS_RE.findall(ln):
                    fusion_comps.add(cal)

    # Effective operand sizes for fusion parameters consumed ONLY through
    # dynamic-slice: the fusion reads the slice, not the stacked buffer
    # (critical for scanned-layer models, where every weight is a slice of
    # an (L, ...) array and the loop multiplier would 28x-overcount reads).
    fusion_param_bytes = {}      # comp -> {param_index: effective_bytes}
    fusion_out_bytes = {}        # comp -> effective result bytes (aliased
    #                              DUS-rooted fusions update in place)
    for fname in fusion_comps:
        lines = comps.get(fname, [])
        param_idx, slice_bytes, other_use = {}, {}, set()
        types_f = {}
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            iname, type_str, kind = im.groups()
            types_f[iname] = type_str
            if kind == "parameter":
                pm = re.search(r"parameter\((\d+)\)", ln)
                if pm:
                    param_idx[iname] = int(pm.group(1))
                continue
            args = ln[im.end():]
            arg_str = args.split("), ")[0]
            names = _OPERANDS_RE.findall(arg_str)
            if ln.startswith("ROOT") and kind == "dynamic-update-slice":
                upd_t = types_f.get(names[1]) if len(names) > 1 else None
                if upd_t:  # in-place window update, not a full rewrite
                    fusion_out_bytes[fname] = 2 * sum(_shapes_in(upd_t))
            for j, op_name in enumerate(names):
                if op_name not in param_idx:
                    continue
                if kind == "dynamic-slice" and j == 0:
                    slice_bytes[op_name] = slice_bytes.get(op_name, 0) \
                        + sum(_shapes_in(type_str))
                elif kind == "dynamic-update-slice" and j == 0 \
                        and ln.startswith("ROOT"):
                    # the updated buffer param aliases the output: its
                    # read traffic is covered by fusion_out_bytes
                    slice_bytes.setdefault(op_name, 0)
                else:
                    other_use.add(op_name)
        eff = {param_idx[p]: b for p, b in slice_bytes.items()
               if p not in other_use}
        if eff:
            fusion_param_bytes[fname] = eff

    def mult(comp):
        return _mult(comp, trip, caller)

    flops = 0.0
    byts = 0.0
    by_comp = {}
    for name, lines in comps.items():
        mm = mult(name)
        types = {}
        comp_flops = 0.0
        schedulable = name not in fusion_comps
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            iname, type_str, kind = im.groups()
            types[iname] = type_str
            # ---- FLOPs: dot ops anywhere --------------------------------
            if kind == "dot":
                dims = _first_dims(type_str)
                out_n = 1
                for d in (dims or []):
                    out_n *= d
                k = 1
                cm = _LHS_CDIMS_RE.search(ln)
                args = ln[ln.index("dot(") + 4:]
                ops_names = _OPERANDS_RE.findall(
                    args[:args.index(")")] if ")" in args else args)
                if cm and ops_names:
                    lhs_t = types.get(ops_names[0])
                    if lhs_t is not None:
                        ldims = _first_dims(lhs_t) or []
                        for ci in cm.group(1).split(","):
                            if ci and int(ci) < len(ldims):
                                k *= ldims[int(ci)]
                comp_flops += 2.0 * out_n * k
            # ---- HBM bytes: scheduled instructions only ------------------
            if schedulable and kind not in _NO_TRAFFIC:
                paren = ln[im.end():]
                arg_str = paren.split("), ")[0]
                ops_names = _OPERANDS_RE.findall(arg_str)
                if kind == "dynamic-slice":
                    # reads only the slice it produces, not the buffer
                    total = 2 * sum(_shapes_in(type_str))
                elif kind == "dynamic-update-slice":
                    # reads + writes only the update window (in-place)
                    upd_t = types.get(ops_names[1]) if len(ops_names) > 1 \
                        else None
                    total = (2 * sum(_shapes_in(upd_t)) if upd_t
                             else sum(_shapes_in(type_str)))
                else:
                    eff = {}
                    out_b = None
                    if kind == "fusion":
                        cm = _CALLS_RE.search(ln)
                        if cm:
                            eff = fusion_param_bytes.get(cm.group(1), {})
                            out_b = fusion_out_bytes.get(cm.group(1))
                    total = (out_b if out_b is not None
                             else sum(_shapes_in(type_str)))
                    for j, op_name in enumerate(ops_names):
                        if j in eff:
                            total += eff[j]
                            continue
                        t = types.get(op_name)
                        if t is not None:
                            total += sum(_shapes_in(t))
                byts += total * mm
        flops += comp_flops * mm
        if comp_flops:
            by_comp[name] = comp_flops * mm
    return {"flops": float(flops), "bytes": float(byts),
            "dot_flops_by_comp": by_comp}
