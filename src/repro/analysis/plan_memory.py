"""Plan memory audit: padded vs live bytes across the scenario catalog.

The compiled replay pipeline pads every plan segment twice — the message
axis to a power-of-two capacity bucket (``bucket_cap``, floor
``BUCKET_MIN`` = 64) and the step axis to a shared per-cap step bucket
(``step_bucket`` / ``MAX_STEP_PAD``).  Padding buys bounded compile
counts, but every padded slot is resident device memory AND an inner-scan
iteration of the executor, so at 1000+-node scale dead slots are both an
HBM and a wall-clock tax.  This module measures that tax (DESIGN.md §9):

* :func:`audit_plan` — per-segment ``(cap, S_pad, padded_bytes,
  live_bytes, waste)`` rows for one compiled plan, from the
  ``host_live`` message counts the planner records per step;
* :func:`audit_catalog` — the whole scenario catalog at a given topology
  scale, with a pow2 vs ragged (``repack_plans``) comparison per
  stackable group;
* CLI — ``python -m repro.analysis.plan_memory --scales 80,256,1024``
  prints the DESIGN.md padding-waste table.

"Live" bytes are the bytes a hypothetical exact-fit layout would hold:
per real step, the fixed per-step arrays plus ``live`` message slots.
The waste ratio ``1 - live/padded`` is therefore the fraction of plan
memory (and inner-scan work) spent on padding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.traffic.plan import (
    TracePlan, compile_plan, group_stackable, plan_nbytes, repack_plans,
    segment_nbytes, slot_nbytes, step_fixed_nbytes)


@dataclass
class SegmentAudit:
    """Padding accounting for one plan segment."""
    cap: int
    s_pad: int
    n_steps: int
    padded_bytes: int
    live_bytes: int
    wave_width: int = 0
    mean_live: float = 0.0
    mean_wave: float = 0.0

    @property
    def waste(self) -> float:
        """Fraction of the segment's bytes that are padding."""
        if self.padded_bytes == 0:
            return 0.0
        return 1.0 - self.live_bytes / self.padded_bytes

    @property
    def wave_contraction(self) -> float:
        """Inner-loop trip-count ratio wavefront buys: wave_width / cap
        (1.0 = no win; DESIGN.md §10)."""
        if self.cap == 0:
            return 1.0
        return max(self.wave_width, 1) / self.cap


@dataclass
class PlanAudit:
    """Whole-plan padding accounting (sum over segments)."""
    name: str
    n_nodes: int
    segments: List[SegmentAudit]

    @property
    def padded_bytes(self) -> int:
        return sum(s.padded_bytes for s in self.segments)

    @property
    def live_bytes(self) -> int:
        return sum(s.live_bytes for s in self.segments)

    @property
    def waste(self) -> float:
        pb = self.padded_bytes
        return 1.0 - self.live_bytes / pb if pb else 0.0


def audit_plan(plan: TracePlan, name: Optional[str] = None) -> PlanAudit:
    """Per-segment padded vs live bytes for one compiled plan."""
    n, H = plan.n_nodes, plan.max_hops
    fixed, slot = step_fixed_nbytes(n), slot_nbytes(H)
    segs = []
    for seg in plan.segments:
        live_rows = int(np.count_nonzero(
            np.abs(np.asarray(seg.xs["delta"])).sum(axis=-1) > 0)
        ) if seg.cap == 0 else 0
        real = max(seg.n_steps, live_rows)
        live = real * fixed
        if seg.cap and seg.host_live is not None:
            live += int(seg.host_live.sum()) * slot
        segs.append(SegmentAudit(
            cap=seg.cap, s_pad=seg.s_pad, n_steps=seg.n_steps,
            padded_bytes=segment_nbytes(seg.cap, seg.s_pad, n, H),
            live_bytes=live,
            wave_width=seg.wave_width if seg.cap else 0,
            mean_live=seg.mean_live if seg.cap else 0.0,
            mean_wave=seg.mean_wave if seg.cap else 0.0))
    return PlanAudit(name=name or plan.name or "?", n_nodes=n,
                     segments=segs)


@dataclass
class CatalogAudit:
    """Catalog-wide audit at one topology scale, pow2 vs ragged."""
    n_nodes: int
    plans: List[PlanAudit]                 # pow2 (production default)
    ragged_bytes: int                      # repacked device bytes
    pow2_bytes: int                        # current device bytes

    @property
    def padded_bytes(self) -> int:
        return sum(p.padded_bytes for p in self.plans)

    @property
    def live_bytes(self) -> int:
        return sum(p.live_bytes for p in self.plans)

    @property
    def waste(self) -> float:
        pb = self.padded_bytes
        return 1.0 - self.live_bytes / pb if pb else 0.0

    @property
    def ragged_saving(self) -> float:
        return 1.0 - self.ragged_bytes / self.pow2_bytes \
            if self.pow2_bytes else 0.0

    def worst(self, k: int = 3) -> List[PlanAudit]:
        return sorted(self.plans, key=lambda p: -p.waste)[:k]


def audit_catalog(topo, scenarios=None, n_nodes: Optional[int] = None
                  ) -> CatalogAudit:
    """Audit every catalog scenario compiled against ``topo``.

    The ragged comparison repacks per stackable group (the same grouping
    ``sweep_cells`` batches by), since ``repack_plans`` must keep each
    group on one ``plan_shape_key``.
    """
    from repro.scenarios.spec import build_trace
    from repro.scenarios.suite import resolve

    specs = resolve(scenarios, n_nodes=n_nodes)
    plans, audits = [], []
    for name, spec in specs.items():
        plan = compile_plan(build_trace(spec, topo), topo)
        plans.append(plan)
        audits.append(audit_plan(plan, name))
    pow2 = sum(plan_nbytes(p) for p in plans)
    ragged = 0
    for idxs in group_stackable(plans):
        ragged += sum(plan_nbytes(p)
                      for p in repack_plans([plans[i] for i in idxs]))
    return CatalogAudit(n_nodes=topo.n_nodes, plans=audits,
                        ragged_bytes=ragged, pow2_bytes=pow2)


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

#: DESIGN.md §9 scales: node counts -> small_topology(...) constructor args.
SCALE_TOPOS = {
    80: dict(),                                              # default
    256: dict(n_groups=8, leaves=8, spines=8, nodes_per_leaf=4),
    1024: dict(n_groups=16, leaves=8, spines=8, nodes_per_leaf=8),
}


def scale_topology(n_nodes: int):
    from repro.topology.megafly import small_topology
    if n_nodes not in SCALE_TOPOS:
        raise KeyError(f"no canonical topology for {n_nodes} nodes; "
                       f"have {sorted(SCALE_TOPOS)}")
    return small_topology(**SCALE_TOPOS[n_nodes])


def table(audits: Dict[int, CatalogAudit], fmt: str = "md") -> str:
    """The padding-waste table: one row per (scale, worst offenders)."""
    hdr = ["nodes", "scenarios", "padded_MB", "live_MB", "waste",
           "ragged_MB", "ragged_saving", "worst"]
    lines = []
    if fmt == "md":
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for n in sorted(audits):
        a = audits[n]
        worst = ", ".join(f"{p.name} {p.waste:.0%}" for p in a.worst(3))
        cells = [str(n), str(len(a.plans)),
                 f"{a.padded_bytes / 1e6:.2f}", f"{a.live_bytes / 1e6:.2f}",
                 f"{a.waste:.1%}", f"{a.ragged_bytes / 1e6:.2f}",
                 f"{a.ragged_saving:.1%}", worst]
        lines.append("| " + " | ".join(cells) + " |" if fmt == "md"
                     else ",".join(cells))
    return "\n".join(lines)


def wave_table(audits: Dict[int, CatalogAudit], fmt: str = "md") -> str:
    """Per-catalog wave width / live count vs cap (DESIGN.md §10): how far
    plan-time conflict scheduling contracts the executor's inner message
    loop, and which lowering the ``auto`` cost model picks per segment
    (for a chain-capable proto)."""
    from repro.core import replay as R
    hdr = ["nodes", "cap", "segments", "max_W", "mean_W", "mean_live",
           "mean_W/cap", "auto_pick"]
    lines = []
    if fmt == "md":
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for n in sorted(audits):
        by_cap: Dict[int, List[SegmentAudit]] = {}
        for p in audits[n].plans:
            for s in p.segments:
                if s.cap:
                    by_cap.setdefault(s.cap, []).append(s)
        for cap in sorted(by_cap):
            segs = by_cap[cap]
            ws = [s.wave_width for s in segs]
            picks: Dict[str, int] = {"scan": 0, "prefix": 0, "chain": 0}
            for s in segs:
                costs = {"scan": R.SCAN_SLOT_US * cap,
                         "prefix": R.PREFIX_FIXED_US
                         + R.PREFIX_SLOT_US * s.mean_live,
                         "chain": R.CHAIN_FIXED_US
                         + R.WAVE_US * s.mean_wave}
                picks[min(costs, key=costs.get)] += 1
            pick = "|".join(f"{k}:{v}" for k, v in picks.items() if v)
            cells = [str(n), str(cap), str(len(segs)), str(max(ws)),
                     f"{np.mean(ws):.1f}",
                     f"{np.mean([s.mean_live for s in segs]):.1f}",
                     f"{np.mean([s.wave_contraction for s in segs]):.2f}",
                     pick]
            lines.append("| " + " | ".join(cells) + " |" if fmt == "md"
                         else ",".join(cells))
    return "\n".join(lines)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scales", default="80,256",
                    help="comma list of node counts (80,256,1024)")
    ap.add_argument("--fmt", choices=["md", "csv"], default="md")
    ap.add_argument("--per-plan", action="store_true",
                    help="also print per-scenario rows")
    ap.add_argument("--waves", action="store_true",
                    help="also print the wave width vs cap table")
    args = ap.parse_args(argv)
    audits = {}
    for n in (int(s) for s in args.scales.split(",")):
        audits[n] = audit_catalog(scale_topology(n))
    print(table(audits, args.fmt))
    if args.waves:
        print()
        print(wave_table(audits, args.fmt))
    if args.per_plan:
        for n, a in sorted(audits.items()):
            print(f"\n# {n} nodes")
            for p in sorted(a.plans, key=lambda p: -p.waste):
                print(f"  {p.name:24s} {p.padded_bytes / 1e6:8.2f} MB "
                      f"padded, {p.live_bytes / 1e6:8.2f} MB live, "
                      f"{p.waste:6.1%} waste, "
                      f"{len(p.segments)} segments")


if __name__ == "__main__":
    main()
