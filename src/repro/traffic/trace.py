"""VEF-like trace representation: per-node programs as phase-structured steps.

A Trace is a sequence of Steps over a set of participating nodes (an
*allocation* of global node ids on the full topology — applications in the
paper run on a subset of the 4160-node system while idle nodes draw minimum
power).

Step semantics (superstep / BSP approximation of MPI dependency replay —
see DESIGN.md §3):
  1. each node in ``compute_nodes`` advances its clock by ``compute_secs``;
  2. every message in ``msgs`` [(src, dst, bytes)] is injected at its source's
     clock; deliveries advance destination clocks;
  3. if ``barrier``, all participants synchronize to the max clock.
Collectives are expanded into multiple steps (one per round), so their
internal dependency structure is preserved.

Traces are compiled once per topology into a device-resident
:class:`~repro.traffic.plan.TracePlan` (DESIGN.md §2); the ``version``
counter below lets that plan cache detect builder-API mutation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Step:
    compute_nodes: Optional[np.ndarray] = None   # (K,) global node ids
    compute_secs: Optional[np.ndarray] = None    # (K,) f64 seconds
    msgs: Optional[np.ndarray] = None            # (M,3) int64 [src,dst,bytes]
    barrier: bool = False


@dataclass
class Trace:
    nodes: np.ndarray                            # participating node ids
    steps: List[Step] = field(default_factory=list)
    name: str = ""
    version: int = field(default=0, repr=False, compare=False)

    # -- builder helpers -----------------------------------------------------
    def compute(self, secs):
        """Uniform (or per-node array) compute phase on all participants."""
        secs = np.broadcast_to(np.asarray(secs, np.float64),
                               self.nodes.shape).copy()
        self.steps.append(Step(compute_nodes=self.nodes.copy(),
                               compute_secs=secs))
        self.version += 1
        return self

    def messages(self, msgs, barrier=False):
        msgs = np.asarray(msgs, np.int64).reshape(-1, 3)
        self.steps.append(Step(msgs=msgs, barrier=barrier))
        self.version += 1
        return self

    def rounds(self, rounds, barrier_last=False):
        """Append a list of message rounds (each a (M,3) array)."""
        for i, r in enumerate(rounds):
            self.messages(r, barrier=barrier_last and i == len(rounds) - 1)
        return self

    def barrier(self):
        self.steps.append(Step(barrier=True))
        self.version += 1
        return self

    @property
    def n_messages(self):
        return sum(len(s.msgs) for s in self.steps if s.msgs is not None)

    @property
    def total_bytes(self):
        return sum(int(s.msgs[:, 2].sum()) for s in self.steps
                   if s.msgs is not None)
