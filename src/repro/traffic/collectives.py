"""MPI collective -> message-round expansion.

Ranks live in collective space; ``nodes[rank]`` maps to global node ids
(task mapping).  Algorithms follow standard MPI implementations:

* allreduce: recursive halving-doubling (reduce-scatter + all-gather),
  bandwidth-optimal for large payloads — 2*log2(n) rounds.
* broadcast: binomial tree, log2(n) rounds.
* reduce: reverse binomial tree.
* (all)gather: direct to root / ring.
* alltoall: Bruck, log2(n) rounds of n/2-relative exchanges.

Every function returns a list of (M,3) int64 arrays [src, dst, bytes] — one
per dependency round — suitable for Trace.rounds().
"""
from __future__ import annotations

import numpy as np


def _check(nodes):
    n = len(nodes)
    assert n >= 2 and (n & (n - 1)) == 0, \
        f"collectives require power-of-two participants, got {n}"
    return n


def _round(nodes, pairs_bytes):
    src, dst, b = zip(*pairs_bytes)
    return np.stack([nodes[np.asarray(src)], nodes[np.asarray(dst)],
                     np.asarray(b, np.int64)], axis=1)


def allreduce(nodes, nbytes):
    """Recursive halving-doubling: RS (sizes halve) then AG (sizes double)."""
    nodes = np.asarray(nodes)
    n = _check(nodes)
    logn = n.bit_length() - 1
    rounds = []
    size = nbytes
    # reduce-scatter
    for r in range(logn):
        size = max(size // 2, 1)
        peer = np.arange(n) ^ (1 << r)
        rounds.append(_round(nodes, [(i, int(peer[i]), size)
                                     for i in range(n)]))
    # all-gather
    for r in reversed(range(logn)):
        peer = np.arange(n) ^ (1 << r)
        rounds.append(_round(nodes, [(i, int(peer[i]), size)
                                     for i in range(n)]))
        size *= 2
    return rounds


def broadcast(nodes, nbytes, root=0):
    nodes = np.asarray(nodes)
    n = _check(nodes)
    logn = n.bit_length() - 1
    rounds = []
    vr = (np.arange(n) - root) % n  # virtual ranks, root -> 0
    inv = np.argsort(vr)
    # doubling: at round r only ranks vr < 2^r hold the data; each sends to
    # vr + 2^r, so the holder set doubles per round
    for r in range(logn):
        msgs = []
        for i in range(n):
            if vr[i] < (1 << r) and (vr[i] | (1 << r)) < n:
                msgs.append((i, int(inv[vr[i] | (1 << r)]), nbytes))
        if msgs:
            rounds.append(_round(nodes, msgs))
    return rounds


def reduce(nodes, nbytes, root=0):
    """Reverse binomial tree."""
    nodes = np.asarray(nodes)
    n = _check(nodes)
    logn = n.bit_length() - 1
    rounds = []
    vr = (np.arange(n) - root) % n
    inv = np.argsort(vr)
    # halving (mirror of broadcast): at round r every rank whose bit r is the
    # lowest set bit sends its accumulated partial to vr - 2^r and retires
    for r in range(logn):
        msgs = []
        for i in range(n):
            if vr[i] % (1 << (r + 1)) == (1 << r):
                msgs.append((i, int(inv[vr[i] - (1 << r)]), nbytes))
        if msgs:
            rounds.append(_round(nodes, msgs))
    return rounds


def gather(nodes, nbytes, root=0):
    """Direct gather: every rank sends its block to root (one round; the
    network serializes at the root link, as in reality)."""
    nodes = np.asarray(nodes)
    n = len(nodes)
    return [_round(nodes, [(i, root, nbytes) for i in range(n) if i != root])]


def allgather(nodes, nbytes):
    """Ring all-gather: n-1 rounds of neighbor exchanges."""
    nodes = np.asarray(nodes)
    n = len(nodes)
    return [_round(nodes, [(i, (i + 1) % n, nbytes) for i in range(n)])
            for _ in range(n - 1)]


def alltoall(nodes, nbytes_total):
    """Bruck: log2(n) rounds, each rank sends ~half its buffer 2^r away."""
    nodes = np.asarray(nodes)
    n = _check(nodes)
    logn = n.bit_length() - 1
    per_round = max(nbytes_total // 2, 1)
    rounds = []
    for r in range(logn):
        d = 1 << r
        rounds.append(_round(nodes, [(i, (i + d) % n, per_round)
                                     for i in range(n)]))
    return rounds


def p2p_halo(nodes, nbytes, dims=3):
    """Nearest-neighbor halo exchange on a pseudo-3D process grid
    (LAMMPS-style spatial decomposition): up to 2*dims neighbors each."""
    nodes = np.asarray(nodes)
    n = len(nodes)
    nx = max(int(round(n ** (1 / 3))), 1)
    ny = max(int(round((n // nx) ** 0.5)), 1) if n // nx else 1
    strides = [1, nx, nx * ny][:dims]
    msgs = []
    for s in strides:
        if s >= n:
            break
        for i in range(n):
            msgs.append((i, (i + s) % n, nbytes))
            msgs.append((i, (i - s) % n, nbytes))
    return [_round(nodes, msgs)]
