"""Trace -> TracePlan compiler: device-resident replay plans (DESIGN.md §2).

Replaying a :class:`~repro.traffic.trace.Trace` used to be a host Python
loop: every step re-derived routes, argsorted injection times in numpy,
re-padded messages, and bounced ``ready``-clock state between host and
device.  This module compiles a (trace, topology) pair ONCE into a
:class:`TracePlan` whose arrays live on device, so the executor
(``repro.core.replay``) can run the whole trace as a few ``lax.scan`` calls
with zero per-step host work:

  * **routes**: one batched ``topo.routes_cached`` lookup for ALL messages
    of the trace (the topology-level route LRU serves whole-trace repeats
    — replanned or identically rebuilt traces, fresh equal topologies);
  * **message tables**: per-step (src, dst, bytes, links, dirs, n_hops)
    padded into a small set of shared power-of-two bucket shapes — the
    same bucketing both engines always used, now in one place;
  * **compute / barrier phases**: lowered to dense per-step arrays
    (a (n_nodes,) clock delta + a barrier flag) that become scan-step
    branches in the executor;
  * **segments**: contiguous runs of steps sharing a message bucket are
    stacked into (S, cap, ...) arrays — one compiled scan per segment
    shape.  Step counts are padded to power-of-two buckets as well, so
    compile count is bounded by distinct (cap, S-bucket) pairs, not by
    trace length.

Plans are cached per (trace, topology): every policy group of a sweep —
and every warm rerun — reuses the same device arrays instead of recomputing
routes and padding per group.  The cache keys on trace identity plus a
cheap structural fingerprint; mutating a trace after planning (appending
steps via the builder API) is detected and triggers recompilation.
"""
from __future__ import annotations

import weakref
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

BUCKET_MIN = 64        # smallest message-slot bucket (shared by all engines)
STEP_BUCKET_MIN = 4    # smallest per-segment step-count bucket
MAX_STEP_PAD = 32      # cap on shared-bucket padding of a short segment
RAGGED_MIN = 8         # smallest ragged size-class cap (repack_plans)

PACKINGS = ("pow2", "ragged")


def bucket_cap(M: int, bucket_min: int = BUCKET_MIN) -> int:
    """Power-of-two capacity bucket for M messages (identical bucketing
    across the serial, batched, and plan engines keeps their recompilation
    behaviour aligned).  M <= 1 needs exactly one slot: ``max(M - 1, 0)``
    (NOT ``max(M - 1, 1)``, which silently rounded M=0/M=1 up to a 2-slot
    bucket whenever ``bucket_min`` is 1)."""
    return max(bucket_min, 1 << max(M - 1, 0).bit_length())


def step_bucket(S: int, bucket_min: int = STEP_BUCKET_MIN) -> int:
    """Power-of-two step-count bucket; same S <= 1 edge rule as
    ``bucket_cap`` (a single-step segment buckets to 1, not 2, when
    ``bucket_min`` is 1)."""
    return max(bucket_min, 1 << max(S - 1, 0).bit_length())


def ragged_cap(M: int, ragged_min: int = RAGGED_MIN) -> int:
    """Size-class capacity for M messages: the {2^k, 3*2^(k-1)} ladder
    (8, 12, 16, 24, 32, 48, 64, 96, 128, ...) used by the ragged packer.
    Twice as many classes as the power-of-two ladder bounds worst-case
    slot waste at 33% instead of 50% while keeping the number of distinct
    compiled shapes logarithmic in the largest step."""
    M = max(M, 1)
    if M <= ragged_min:
        return ragged_min
    k = (M - 1).bit_length()             # 2^(k-1) < M <= 2^k
    three_quarter = 3 << (k - 2) if k >= 2 else 1 << k
    return three_quarter if M <= three_quarter else 1 << k


def _pad_axis(a: np.ndarray, cap: int, axis: int, fill=0) -> np.ndarray:
    pad = cap - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return np.pad(a, widths, constant_values=fill)


def pad_message_table(links, dirs, nhops, t_inj, nbytes, *, axis=0,
                      bucket_min: int = BUCKET_MIN):
    """THE shared message-padding helper (serial + batched + plan engines).

    Pads every per-message array along ``axis`` to the power-of-two bucket
    of its current length and returns host numpy
    ``(links, dirs, nhops, t_inj, nbytes, valid)`` — links filled with -1,
    numerics with 0, ``valid`` marking real entries.
    """
    M = nhops.shape[axis]
    cap = bucket_cap(M, bucket_min)
    valid_shape = list(nhops.shape)
    valid_shape[axis] = cap
    valid = np.zeros(valid_shape, bool)
    np.moveaxis(valid, axis, 0)[:M] = True
    return (_pad_axis(links, cap, axis, -1), _pad_axis(dirs, cap, axis),
            _pad_axis(nhops, cap, axis),
            _pad_axis(t_inj.astype(np.float64), cap, axis),
            _pad_axis(nbytes.astype(np.float64), cap, axis), valid)


# ---------------------------------------------------------------------------
# Plan data model
# ---------------------------------------------------------------------------


@dataclass
class _HostStep:
    """One lowered replay step (host-side intermediate): an optional clock
    advance, an optional message table, and an optional barrier — applied
    in that order (DESIGN.md §3)."""
    compute: Optional[tuple] = None      # (nodes (K,), secs (K,))
    msgs: Optional[np.ndarray] = None    # (M, 3) [src, dst, bytes]
    barrier: bool = False


@dataclass
class PlanSegment:
    """A contiguous run of plan steps sharing one message bucket, stacked
    into device arrays with leading dim S (step-count, power-of-two
    padded).  ``xs`` feeds the executor's ``lax.scan`` directly."""
    cap: int                             # message slots per step (0: none)
    n_steps: int                         # real steps before S-padding
    xs: dict = field(repr=False)         # device arrays, leading dim S_pad
    host_has_msgs: np.ndarray = field(default=None, repr=False)  # (S_pad,)
    host_live: np.ndarray = field(default=None, repr=False)      # (S_pad,) i32
    host_wave: np.ndarray = field(default=None, repr=False)      # (S_pad,) i32

    @property
    def s_pad(self) -> int:
        return int(self.xs["delta"].shape[-2])

    @property
    def needs_sort(self) -> bool:
        """False when every step statically carries <=1 valid message: the
        valid slots are a prefix, so the stable injection-time argsort is
        the identity and the executor skips it (plan-time flag)."""
        return self.host_live is None \
            or int(self.host_live.max(initial=0)) > 1

    @property
    def wave_width(self) -> int:
        """Plan-time wave-schedule width (DESIGN.md §10): the largest
        canonical-order conflict-chain length over the segment's steps —
        the wave count the executor's wavefront mode runs when injection
        times tie (the common post-barrier case), and its mode heuristic's
        estimate otherwise.  Segments without the analysis report ``cap``
        (conservative: the serial trip count)."""
        if self.host_wave is None:
            return self.cap
        return int(self.host_wave.max(initial=0))

    @property
    def mean_live(self) -> float:
        """Mean live-message count over the segment's message steps — the
        prefix executor's expected dynamic trip, vs the serial scan's
        static ``cap`` (the executor cost model, DESIGN.md §10).  Stacked
        (T, S) metadata averages over every trace row."""
        if self.host_live is None:
            return float(self.cap)
        lv = self.host_live[self.host_live > 0]
        return float(lv.mean()) if lv.size else 0.0

    @property
    def mean_wave(self) -> float:
        """Mean canonical wave count over the segment's message steps —
        the chained wave executor's expected trip."""
        if self.host_wave is None:
            return float(self.cap)
        wv = self.host_wave[self.host_wave > 0]
        return float(wv.mean()) if wv.size else 0.0

    def nbytes(self) -> int:
        """Device bytes held by this segment's arrays."""
        return sum(int(np.dtype(x.dtype).itemsize) * int(np.prod(x.shape))
                   for x in self.xs.values())


def slot_nbytes(max_hops: int) -> int:
    """Device bytes of ONE message slot: src/dst/nhops i32 + bytes f64 +
    valid bool + per-hop links/dirs i32 pairs."""
    return 4 + 4 + 4 + 8 + 1 + 8 * max_hops


def step_fixed_nbytes(n_nodes: int) -> int:
    """Per-step device bytes independent of the message cap (clock delta +
    barrier / has_msgs flags)."""
    return 8 * n_nodes + 2


def segment_nbytes(cap: int, s_pad: int, n_nodes: int, max_hops: int) -> int:
    """Byte model of a (cap, S_pad) segment — the packer's merge-cost
    metric and the memory audit's padded-bytes column.  Matches
    ``PlanSegment.nbytes()`` for segments built by ``_stack_segment``
    (capped segments also carry a 4-byte per-step live count)."""
    per_step = step_fixed_nbytes(n_nodes) + cap * slot_nbytes(max_hops) \
        + (4 if cap else 0)
    return s_pad * per_step


@dataclass
class TracePlan:
    """A compiled, device-resident replay program for one (trace, topo)."""
    n_nodes: int
    n_links: int
    max_hops: int
    part_mask: jnp.ndarray               # (n_nodes,) bool — participants
    has_participants: bool
    busy: float                          # total compute seconds (node energy)
    n_msgs: int
    n_message_steps: int
    segments: List[PlanSegment]
    name: str = ""
    bucket_min: int = BUCKET_MIN

    @property
    def n_steps(self) -> int:
        return sum(s.n_steps for s in self.segments)

    def describe(self) -> str:
        caps = [f"{s.cap}x{s.n_steps}" for s in self.segments]
        return (f"TracePlan({self.name or 'trace'}: {self.n_msgs} msgs, "
                f"{self.n_steps} steps, segments [{', '.join(caps)}])")


# ---------------------------------------------------------------------------
# Lowering: Trace steps -> _HostSteps (phase fusion)
# ---------------------------------------------------------------------------


def _lower_steps(trace) -> List[_HostStep]:
    """Fuse the trace's phase structure into plan steps.

    A compute-only step fuses into the FOLLOWING message step (the plan
    step applies compute -> msgs -> barrier, exactly the replay order of
    the two originals); a trailing barrier-only step folds into the
    preceding plan step.  Fusion never merges two compute phases into one
    floating-point add, so clock arithmetic stays bit-identical to the
    step-loop reference engine.
    """
    out: List[_HostStep] = []
    pending: Optional[tuple] = None      # one unconsumed compute phase

    def flush():
        nonlocal pending
        if pending is not None:
            out.append(_HostStep(compute=pending))
            pending = None

    for st in trace.steps:
        has_c = st.compute_nodes is not None and len(st.compute_nodes) > 0
        has_m = st.msgs is not None and len(st.msgs) > 0
        if has_c and not has_m and not st.barrier:
            flush()
            pending = (st.compute_nodes, st.compute_secs)
            continue
        if not has_m and not st.barrier:
            continue                     # fully empty step: no-op
        if has_c:
            flush()
            comp = (st.compute_nodes, st.compute_secs)
        else:
            comp, pending = pending, None
        if not has_m and st.barrier and comp is None and out \
                and not out[-1].barrier:
            out[-1].barrier = True       # retrofit: phases then barrier
            continue
        out.append(_HostStep(compute=comp,
                             msgs=st.msgs if has_m else None,
                             barrier=st.barrier))
    flush()
    return out


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------


def step_conflicts(links: np.ndarray, nhops: np.ndarray) -> np.ndarray:
    """(M, M) bool conflict matrix of one step's messages: i conflicts j
    iff their route link sets intersect (direction-agnostic — both
    directions of a link share its FSM row).  Messages sharing a link form
    a clique, so the matrix assembles per-link instead of via an O(M²H²)
    pairwise compare.  Diagonal is False."""
    M = links.shape[0]
    conf = np.zeros((M, M), bool)
    if M <= 1:
        return conf
    hop_ok = (links >= 0) & (np.arange(links.shape[1]) < nhops[:, None])
    mi, hi = np.nonzero(hop_ok)
    by_link: dict = {}
    for i, l in zip(mi.tolist(), links[mi, hi].tolist()):
        by_link.setdefault(l, []).append(i)
    for idx in by_link.values():
        if len(idx) > 1:
            conf[np.ix_(idx, idx)] = True
    np.fill_diagonal(conf, False)
    return conf


def wave_assign(conf: np.ndarray) -> np.ndarray:
    """Order-preserving greedy wave ids (1-based) for a step's messages in
    a fixed processing order: ``wave[i] = 1 + max(wave[j])`` over earlier
    conflicting ``j`` (0 if none).  Conflicting pairs land in strictly
    increasing waves matching the order, so executing wave-by-wave — each
    wave's (link-disjoint) members batched — replays the exact serial
    update sequence on every FSM row (DESIGN.md §10).  The executor runs
    the same recurrence on device against each lane's injection-time sort;
    this host twin (canonical slot order) feeds the plan-time width
    estimate and the property tests."""
    M = conf.shape[0]
    wave = np.ones(M, np.int64)
    for i in range(1, M):
        pred = conf[i, :i]
        if pred.any():
            wave[i] = wave[:i][pred].max() + 1
    return wave


def _step_wave_width(links: np.ndarray, nhops: np.ndarray) -> int:
    """Wave count of one step in canonical (slot) order — exact when
    injection times tie (stable sort = identity), the mode heuristic's
    estimate otherwise."""
    M = links.shape[0]
    if M <= 1:
        return M
    return int(wave_assign(step_conflicts(links, nhops)).max())


def _stack_segment(steps: List[_HostStep], cap: int, n_nodes: int,
                   routed: dict, H: int, S_pad: int) -> PlanSegment:
    S = len(steps)
    delta = np.zeros((S_pad, n_nodes), np.float64)
    barrier = np.zeros((S_pad,), bool)
    has_msgs = np.zeros((S_pad,), bool)
    live = np.zeros((S_pad,), np.int32)
    wave = np.zeros((S_pad,), np.int32)
    xs = {}
    if cap:
        src = np.zeros((S_pad, cap), np.int32)
        dst = np.zeros((S_pad, cap), np.int32)
        nbytes = np.zeros((S_pad, cap), np.float64)
        links = np.full((S_pad, cap, H), -1, np.int32)
        dirs = np.zeros((S_pad, cap, H), np.int32)
        nhops = np.zeros((S_pad, cap), np.int32)
        valid = np.zeros((S_pad, cap), bool)
    for i, ps in enumerate(steps):
        if ps.compute is not None:
            nodes, secs = ps.compute
            # assignment (not add.at): matches the reference engine's
            # buffered fancy-index `ready[nodes] += secs`
            delta[i][np.asarray(nodes)] = np.asarray(secs, np.float64)
        barrier[i] = ps.barrier
        if ps.msgs is not None:
            M = len(ps.msgs)
            has_msgs[i] = True
            live[i] = M
            src[i, :M] = ps.msgs[:, 0]
            dst[i, :M] = ps.msgs[:, 1]
            nbytes[i, :M] = ps.msgs[:, 2].astype(np.float64)
            l, d, nh = routed[id(ps)]
            links[i, :M] = l
            dirs[i, :M] = d
            nhops[i, :M] = nh
            valid[i, :M] = True
            wave[i] = _step_wave_width(np.asarray(l), np.asarray(nh))
    xs["delta"] = jnp.asarray(delta)
    xs["barrier"] = jnp.asarray(barrier)
    if cap:
        xs.update(
            has_msgs=jnp.asarray(has_msgs), live=jnp.asarray(live),
            src=jnp.asarray(src),
            dst=jnp.asarray(dst), nbytes=jnp.asarray(nbytes),
            links=jnp.asarray(links), dirs=jnp.asarray(dirs),
            nhops=jnp.asarray(nhops), valid=jnp.asarray(valid))
    return PlanSegment(cap=cap, n_steps=S, xs=xs, host_has_msgs=has_msgs,
                       host_live=live, host_wave=wave)


def topo_signature(topo) -> tuple:
    """``(n_nodes, n_links, max_hops)`` — the topology part of a plan's
    compiled shape (``RoutedTopology.signature`` when available)."""
    if hasattr(topo, "signature"):
        return topo.signature()
    return (topo.n_nodes, topo.n_links, topo.max_hops)


def _compile(trace, topo, bucket_min: int) -> TracePlan:
    steps = _lower_steps(trace)
    n_nodes, n_links, H = topo_signature(topo)

    # ---- one batched route lookup for the whole trace -------------------
    msg_steps = [ps for ps in steps if ps.msgs is not None]
    routed: dict = {}
    if msg_steps:
        all_src = np.concatenate([ps.msgs[:, 0] for ps in msg_steps])
        all_dst = np.concatenate([ps.msgs[:, 1] for ps in msg_steps])
        lookup = getattr(topo, "routes_cached", topo.routes)
        links, dirs, nhops = lookup(all_src, all_dst)
        off = 0
        for ps in msg_steps:
            M = len(ps.msgs)
            routed[id(ps)] = (links[off:off + M], dirs[off:off + M],
                              nhops[off:off + M])
            off += M

    # ---- segmentation: contiguous runs sharing one bucket ---------------
    runs: List[tuple] = []               # (steps, cap)
    run: List[_HostStep] = []
    run_cap: Optional[int] = None        # None until a message step joins
    for ps in steps:
        c = bucket_cap(len(ps.msgs), bucket_min) if ps.msgs is not None \
            else None
        if run and c is not None and run_cap is not None and c != run_cap:
            runs.append((run, run_cap))
            run, run_cap = [], None
        run.append(ps)
        if c is not None:
            run_cap = run_cap or c
    if run:
        runs.append((run, run_cap or 0))

    # One shared step-count bucket per cap: same-cap segments pad to the
    # longest run's bucket, so the executor compiles ONE program per
    # (static structure, cap) — no-op pad steps are a cheap cond-false,
    # extra program shapes are a ~seconds compile each.  The pad factor is
    # bounded (MAX_STEP_PAD): on fragmented traces a short segment never
    # pads past MAX_STEP_PAD x its own bucket, trading at most a couple of
    # extra program shapes for O(longest-run) pad work per fragment.
    cap_bucket = {}
    for seg_steps, cap in runs:
        cap_bucket[cap] = max(cap_bucket.get(cap, 0),
                              step_bucket(len(seg_steps)))
    segments = [
        _stack_segment(seg_steps, cap, n_nodes, routed, H,
                       min(cap_bucket[cap],
                           MAX_STEP_PAD * step_bucket(len(seg_steps))))
        for seg_steps, cap in runs]

    # ---- host-scalar bookkeeping (accumulation order matches the
    #      reference engine exactly) --------------------------------------
    busy = 0.0
    for st in trace.steps:
        if st.compute_nodes is not None and len(st.compute_nodes):
            busy += float(st.compute_secs.sum())

    part_mask = np.zeros(n_nodes, bool)
    part_mask[np.asarray(trace.nodes, np.int64)] = True

    return TracePlan(
        n_nodes=n_nodes, n_links=n_links, max_hops=H,
        part_mask=jnp.asarray(part_mask),
        has_participants=len(trace.nodes) > 0,
        busy=busy, n_msgs=int(trace.n_messages),
        n_message_steps=len(msg_steps), segments=segments,
        name=trace.name, bucket_min=bucket_min)


# ---------------------------------------------------------------------------
# Per-(trace, topo) plan cache
# ---------------------------------------------------------------------------

# id(trace) -> (weakref(trace), fingerprint, {topo: TracePlan})
_PLAN_CACHE: dict = {}
_CACHE_STATS = {"hits": 0, "misses": 0, "stack_hits": 0, "stack_misses": 0}


def _fingerprint(trace) -> tuple:
    return (len(trace.steps), trace.n_messages,
            getattr(trace, "version", 0))


def plan_nbytes(plan) -> int:
    """Resident device bytes of a :class:`TracePlan` / :class:`PlanBatch`
    (segment arrays + participant mask)."""
    n = sum(seg.nbytes() for seg in plan.segments)
    pm = plan.part_mask
    return n + int(np.dtype(pm.dtype).itemsize) * int(np.prod(pm.shape))


def compile_plan(trace, topo, bucket_min: int = BUCKET_MIN) -> TracePlan:
    """Compile (or fetch the cached) TracePlan for a (trace, topo) pair.

    The cache keys on trace identity + a structural fingerprint (step and
    message counts, builder version): every sweep group and warm rerun hits
    the same plan, while builder-API mutation after planning recompiles.
    """
    key = id(trace)
    entry = _PLAN_CACHE.get(key)
    fp = _fingerprint(trace)
    if entry is None or entry[0]() is not trace or entry[1] != fp:
        ref = weakref.ref(trace, lambda _r, k=key: _PLAN_CACHE.pop(k, None))
        entry = (ref, fp, {})
        _PLAN_CACHE[key] = entry
    plans = entry[2]
    ck = (topo, bucket_min)
    if ck not in plans:
        _CACHE_STATS["misses"] += 1
        plans[ck] = _compile(trace, topo, bucket_min)
    else:
        _CACHE_STATS["hits"] += 1
    return plans[ck]


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()
    _STACK_CACHE.clear()
    for k in _CACHE_STATS:
        _CACHE_STATS[k] = 0


def plan_cache_info() -> dict:
    """Cache counter surface: per-(trace, topo) plan cache hit/miss counts
    and resident device bytes, plus the same for the stack-level cache
    (``stack_plans_cached``) the sharded sweep engine rides."""
    plans = [p for e in _PLAN_CACHE.values() for p in e[2].values()]
    stacks = [b for _k, b in _STACK_CACHE.values()]
    return {"traces": len(_PLAN_CACHE), "plans": len(plans),
            "hits": _CACHE_STATS["hits"],
            "misses": _CACHE_STATS["misses"],
            "resident_bytes": sum(plan_nbytes(p) for p in plans),
            "stacks": len(_STACK_CACHE),
            "stack_hits": _CACHE_STATS["stack_hits"],
            "stack_misses": _CACHE_STATS["stack_misses"],
            "stack_resident_bytes": sum(plan_nbytes(b) for b in stacks)}


def format_cache_info(info: Optional[dict] = None) -> str:
    """One-line human-readable ``plan_cache_info`` rendering (the
    run_suite / tune_policies log line)."""
    i = info if info is not None else plan_cache_info()
    return (f"plan cache: {i['plans']} plans / {i['traces']} traces, "
            f"{i['hits']} hits / {i['misses']} misses, "
            f"{i['resident_bytes'] / 1e6:.2f} MB resident; "
            f"stacks: {i['stacks']} cached, "
            f"{i['stack_hits']} hits / {i['stack_misses']} misses, "
            f"{i['stack_resident_bytes'] / 1e6:.2f} MB resident")


# ---------------------------------------------------------------------------
# Multi-trace stacking: same-shape plans batch along a second vmapped axis
# ---------------------------------------------------------------------------


def plan_shape_key(plan: TracePlan) -> tuple:
    """Compiled-shape signature of a plan: topology shape + the per-segment
    ``(cap, S_pad)`` schedule.  Two plans with equal keys lower to identical
    executor programs, so they can stack along a leading trace axis and a
    (scenarios x policies) grid replays in ONE compiled scan per segment
    shape instead of one per (scenario, policy-group)."""
    return (plan.n_nodes, plan.n_links, plan.max_hops, plan.bucket_min,
            tuple((s.cap, int(s.xs["delta"].shape[0]))
                  for s in plan.segments))


@dataclass
class PlanBatch:
    """T same-shape TracePlans stacked along a leading trace axis.

    Mirrors :class:`TracePlan` with every device array gaining a leading
    ``T`` dim: segment ``xs`` arrays are ``(T, S_pad, ...)`` and
    ``part_mask`` is ``(T, n_nodes)``.  Host bookkeeping (``busy``,
    ``n_msgs``, participant flags) becomes per-trace numpy vectors.  The
    executor (``repro.core.replay``) vmaps its per-trace program over this
    axis, so one compiled program serves the whole (trace, policy) grid of
    a segment shape.
    """
    n_nodes: int
    n_links: int
    max_hops: int
    part_mask: jnp.ndarray               # (T, n_nodes) bool
    has_participants: np.ndarray         # (T,) bool, host
    busy: np.ndarray                     # (T,) f64, host
    n_msgs: np.ndarray                   # (T,) i64, host
    segments: List[PlanSegment]          # xs arrays lead with T
    names: List[str]
    bucket_min: int = BUCKET_MIN

    @property
    def n_traces(self) -> int:
        return len(self.names)

    def describe(self) -> str:
        caps = [f"{s.cap}x{s.n_steps}" for s in self.segments]
        return (f"PlanBatch({self.n_traces} traces "
                f"[{', '.join(self.names)}]: segments [{', '.join(caps)}])")


def stack_plans(plans: List[TracePlan], names: Optional[List[str]] = None
                ) -> PlanBatch:
    """Stack same-shape plans into one :class:`PlanBatch`.

    All plans must share ``plan_shape_key`` (same topology shape and the
    same per-segment ``(cap, S_pad)`` schedule) — use ``group_stackable``
    to partition an arbitrary plan list first.  A single plan stacks into
    a T=1 batch, so callers can route everything through the multi-trace
    executor unconditionally.
    """
    assert plans, "stack_plans needs at least one plan"
    key0 = plan_shape_key(plans[0])
    for p in plans[1:]:
        assert plan_shape_key(p) == key0, \
            f"cannot stack plans with different shapes: " \
            f"{plan_shape_key(p)} vs {key0}"
    names = list(names) if names is not None \
        else [p.name or f"trace{i}" for i, p in enumerate(plans)]
    segments = []
    for si, seg0 in enumerate(plans[0].segments):
        xs = {k: jnp.stack([p.segments[si].xs[k] for p in plans])
              for k in seg0.xs}
        host_has = np.stack([p.segments[si].host_has_msgs
                             for p in plans]) \
            if seg0.host_has_msgs is not None else None
        host_live = np.stack([p.segments[si].host_live for p in plans]) \
            if seg0.host_live is not None else None
        host_wave = np.stack([p.segments[si].host_wave for p in plans]) \
            if all(p.segments[si].host_wave is not None for p in plans) \
            else None
        segments.append(PlanSegment(
            cap=seg0.cap,
            n_steps=max(p.segments[si].n_steps for p in plans),
            xs=xs, host_has_msgs=host_has, host_live=host_live,
            host_wave=host_wave))
    return PlanBatch(
        n_nodes=plans[0].n_nodes, n_links=plans[0].n_links,
        max_hops=plans[0].max_hops,
        part_mask=jnp.stack([p.part_mask for p in plans]),
        has_participants=np.asarray([p.has_participants for p in plans]),
        busy=np.asarray([p.busy for p in plans], np.float64),
        n_msgs=np.asarray([p.n_msgs for p in plans], np.int64),
        segments=segments, names=names, bucket_min=plans[0].bucket_min)


def group_stackable(plans: List[TracePlan]) -> List[List[int]]:
    """Partition plan indices into stackable groups (equal
    ``plan_shape_key``), preserving first-seen order."""
    groups: dict = {}
    for i, p in enumerate(plans):
        groups.setdefault(plan_shape_key(p), []).append(i)
    return list(groups.values())


# ---------------------------------------------------------------------------
# Ragged repacking: size-class caps + tail-segment merging, stack-uniform
# ---------------------------------------------------------------------------


def _seg_host_xs(seg: PlanSegment, cap: int, H: int) -> dict:
    """One segment's arrays as host numpy, cap axis resized to ``cap``.

    Shrinking slices the (always-prefix) live slots; growing pads with
    inert slots (links -1, numerics 0, valid False).  A cap-0 segment
    materializes an all-inert message table so it can merge into a capped
    neighbour."""
    S = seg.s_pad
    out = {k: np.asarray(v) for k, v in seg.xs.items()}
    if seg.cap == 0 and cap:
        out.update(
            has_msgs=np.zeros((S,), bool),
            live=np.zeros((S,), np.int32),
            src=np.zeros((S, cap), np.int32),
            dst=np.zeros((S, cap), np.int32),
            nbytes=np.zeros((S, cap), np.float64),
            links=np.full((S, cap, H), -1, np.int32),
            dirs=np.zeros((S, cap, H), np.int32),
            nhops=np.zeros((S, cap), np.int32),
            valid=np.zeros((S, cap), bool))
        return out
    if cap < seg.cap:
        for k in ("src", "dst", "nbytes", "links", "dirs", "nhops", "valid"):
            out[k] = out[k][:, :cap]
    elif cap > seg.cap:
        for k in ("src", "dst", "nbytes", "nhops", "valid"):
            out[k] = _pad_axis(out[k], cap, 1)
        out["links"] = _pad_axis(out["links"], cap, 1, -1)
        out["dirs"] = _pad_axis(out["dirs"], cap, 1)
    return out


def _apply_schedule(plan: TracePlan, schedule: List[tuple]) -> TracePlan:
    """Materialize a repack ``schedule`` — ``[(members, cap, S_pad), ...]``
    with ``members`` = ``[(segment_index, keep_rows), ...]`` — for one
    plan.  Each member keeps its first ``keep_rows`` step rows (the
    group-wide real step count: everything beyond is shared-bucket
    padding) and members concatenate along the step axis.  Internal rows
    past a plan's OWN real steps stay as the executor's no-op padding
    (has_msgs False, zero clock delta, no barrier), so every plan of a
    stack group lands on identical array shapes."""
    H = plan.max_hops
    segments = []
    for members, cap, S_pad in schedule:
        segs = [plan.segments[si] for si, _ in members]
        hxs = [{k: v[:keep] for k, v in _seg_host_xs(s, cap, H).items()}
               for s, (_, keep) in zip(segs, members)]
        keys = ["delta", "barrier"] + (
            ["has_msgs", "live", "src", "dst", "nbytes", "links", "dirs",
             "nhops", "valid"] if cap else [])
        xs = {k: np.concatenate([h[k] for h in hxs]) for k in keys}
        S = xs["delta"].shape[0]
        for k in keys:
            xs[k] = _pad_axis(xs[k], S_pad, 0,
                              -1 if k == "links" else 0)
        host_has = _pad_axis(np.concatenate(
            [s.host_has_msgs[:keep]
             for s, (_, keep) in zip(segs, members)]), S_pad, 0)
        host_live = _pad_axis(np.concatenate(
            [s.host_live[:keep]
             for s, (_, keep) in zip(segs, members)]), S_pad, 0)
        # per-step wave widths ride along unchanged — repacking moves and
        # trims padding slots, never the live message set of a step
        host_wave = _pad_axis(np.concatenate(
            [s.host_wave[:keep] if s.host_wave is not None
             else np.zeros((keep,), np.int32)
             for s, (_, keep) in zip(segs, members)]), S_pad, 0)
        segments.append(PlanSegment(
            cap=cap, n_steps=S,
            xs={k: jnp.asarray(v) for k, v in xs.items()},
            host_has_msgs=host_has, host_live=host_live,
            host_wave=host_wave))
    return replace(plan, segments=segments)


def repack_plans(plans: List[TracePlan],
                 ragged_min: int = RAGGED_MIN) -> List[TracePlan]:
    """Jointly repack same-shape plans into ragged size-class segments.

    The memory-audit remedy (DESIGN.md §9): power-of-two buckets with
    ``BUCKET_MIN`` = 64 leave 70–94% of message slots as padding across the
    catalog, and the executor's inner scan walks every padded slot.  This
    pass, applied to a WHOLE stackable group at once so the repacked plans
    still share one ``plan_shape_key`` (the contract every batching layer
    leans on):

      * **shrinks caps to size classes** — each segment's cap drops to the
        ``ragged_cap`` class of the largest LIVE step across the group
        (splitting the oversized power-of-two bucket; never grows);
      * **merges tail segments** — adjacent segments merge greedily into
        the larger cap whenever the byte model (``segment_nbytes``) says
        the merged segment is cheaper than the step-bucket padding of two
        separate ones (fragmented traces collapse to few segments, fewer
        compiled shapes);
      * re-applies the shared same-cap step-bucket rule of ``_compile``
        (bounded by ``MAX_STEP_PAD``), so compile counts stay bounded by
        distinct (cap, S-bucket) pairs exactly as before.

    Results are bit-identical to the power-of-two plans: padding slots are
    masked out of every state update and reduction (``tests/
    test_plan_memory.py`` pins ragged == pow2 == serial reference).
    Returns the input list unchanged when no segment shrinks or merges.
    """
    assert plans, "repack_plans needs at least one plan"
    key0 = plan_shape_key(plans[0])
    for p in plans[1:]:
        assert plan_shape_key(p) == key0, \
            "repack_plans operates on one stackable group at a time"
    n_nodes, H = plans[0].n_nodes, plans[0].max_hops
    segs0 = plans[0].segments

    # -- per-segment joint size class (shrink only) -----------------------
    caps = []
    for si, seg in enumerate(segs0):
        if seg.cap == 0:
            caps.append(0)
            continue
        mx = max(int(p.segments[si].host_live.max(initial=0))
                 for p in plans)
        caps.append(min(seg.cap, ragged_cap(mx, ragged_min)))

    # Group-wide real step counts: rows beyond them are shared-bucket
    # padding every plan agrees on, so the repack drops them up front and
    # re-pads once at the end (they are what makes short tail fragments
    # expensive and mergeable).
    reals = [max(p.segments[si].n_steps for p in plans)
             for si in range(len(segs0))]

    # -- greedy adjacent merging on the byte model ------------------------
    groups = [[(si, reals[si])] for si in range(len(segs0))]
    gcaps = list(caps)
    glens = list(reals)                      # concatenated real steps

    def cost(cap: int, s: int) -> int:
        return segment_nbytes(cap, step_bucket(s), n_nodes, H)

    merged = True
    while merged and len(groups) > 1:
        merged = False
        best = None
        for i in range(len(groups) - 1):
            cap_m = max(gcaps[i], gcaps[i + 1])
            save = (cost(gcaps[i], glens[i])
                    + cost(gcaps[i + 1], glens[i + 1])
                    - cost(cap_m, glens[i] + glens[i + 1]))
            if save > 0 and (best is None or save > best[0]):
                best = (save, i)
        if best is not None:
            _, i = best
            groups[i] = groups[i] + groups.pop(i + 1)
            gcaps[i] = max(gcaps[i], gcaps.pop(i + 1))
            glens[i] = glens[i] + glens.pop(i + 1)
            merged = True

    # -- shared same-cap step buckets (mirrors _compile) ------------------
    cap_bucket: dict = {}
    for cap, s in zip(gcaps, glens):
        cap_bucket[cap] = max(cap_bucket.get(cap, 0), step_bucket(s))
    spads = [min(cap_bucket[cap], MAX_STEP_PAD * step_bucket(s))
             for cap, s in zip(gcaps, glens)]

    schedule = list(zip(groups, gcaps, spads))
    if all(len(m) == 1 and cap == segs0[m[0][0]].cap
           and sp == segs0[m[0][0]].s_pad
           for m, cap, sp in schedule):
        return list(plans)               # nothing to gain — keep originals
    return [_apply_schedule(p, schedule) for p in plans]


# ---------------------------------------------------------------------------
# Stack-level cache: (plans, packing) -> PlanBatch, shared by warm sweeps
# ---------------------------------------------------------------------------

# (plan ids, names, packing) -> ((plans strong refs), PlanBatch); LRU
_STACK_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_STACK_CACHE_MAX = 64


def stack_plans_cached(plans: List[TracePlan],
                       names: Optional[List[str]] = None,
                       packing: str = "pow2") -> PlanBatch:
    """``stack_plans`` behind a bounded LRU, with optional ragged repacking.

    Stacking re-uploads every segment array (``jnp.stack``); the tuner's
    refinement rounds and every warm sweep used to pay that per call.  The
    cache keys on plan identity (stable through the per-(trace, topo) plan
    cache) + the packing mode, so a warm rerun reuses the stacked — and,
    under ``packing='ragged'``, repacked — device arrays outright.  The
    sharded engine (``repro.distributed.shard_sweep``) keys its per-device
    placement off these batches, giving the device-local
    (trace, topo, shard) plan-cache chain.
    """
    assert packing in PACKINGS, f"packing {packing!r} not in {PACKINGS}"
    names = list(names) if names is not None \
        else [p.name or f"trace{i}" for i, p in enumerate(plans)]
    key = (tuple(id(p) for p in plans), tuple(names), packing)
    hit = _STACK_CACHE.get(key)
    if hit is not None:
        _CACHE_STATS["stack_hits"] += 1
        _STACK_CACHE.move_to_end(key)
        return hit[1]
    _CACHE_STATS["stack_misses"] += 1
    packed = repack_plans(plans) if packing == "ragged" else plans
    batch = stack_plans(packed, names)
    _STACK_CACHE[key] = (tuple(plans), batch)
    while len(_STACK_CACHE) > _STACK_CACHE_MAX:
        _STACK_CACHE.popitem(last=False)
    return batch
