"""VEF-like trace file persistence.

The paper's evaluation replays VEF-TraceLib traces captured from real MPI
runs.  Those files are not redistributable, so `repro.traffic.generators`
synthesizes equivalent structures — but a production deployment ingests
captured traces.  This module defines a compact on-disk format with the
same phase-structured semantics as ``repro.traffic.trace.Trace`` so real
captures can be converted once and replayed forever:

    <name>.npz
      nodes            (N,)  int64    participating global node ids
      step_kind        (S,)  uint8    0=compute 1=messages 2=barrier
      comp_ptr         (S+1,) int64   CSR offsets into comp_node/secs
      comp_node        (Kc,) int64
      comp_secs        (Kc,) float64
      msg_ptr          (S+1,) int64   CSR offsets into msgs
      msgs             (Km,3) int64   [src, dst, bytes]
      msg_barrier      (S,)  uint8    barrier flag on message steps

Messages-with-barrier and standalone barriers both round-trip.  The
format is numpy-portable (no pickle), versioned via an ``meta`` array.
"""
from __future__ import annotations

import numpy as np

from repro.traffic.trace import Step, Trace

FORMAT_VERSION = 1


def _split_steps(steps):
    """Normalize to single-phase steps: a Step carrying BOTH compute and
    messages (legal in the data model — phase fusion produces them) splits
    into compute-then-messages, the exact replay order of the fused form,
    so the on-disk single-phase encoding loses nothing."""
    for s in steps:
        has_c = s.compute_nodes is not None and len(s.compute_nodes)
        has_m = s.msgs is not None and len(s.msgs)
        if has_c and (has_m or s.barrier):
            yield Step(compute_nodes=s.compute_nodes,
                       compute_secs=s.compute_secs)
            yield Step(msgs=s.msgs if has_m else None, barrier=s.barrier)
        else:
            yield s


def save_trace(path, trace: Trace) -> None:
    kinds, comp_ptr, comp_node, comp_secs = [], [0], [], []
    msg_ptr, msgs, msg_barrier = [0], [], []
    for s in _split_steps(trace.steps):
        if s.compute_nodes is not None and len(s.compute_nodes):
            kinds.append(0)
            comp_node.append(np.asarray(s.compute_nodes, np.int64))
            comp_secs.append(np.asarray(s.compute_secs, np.float64))
            comp_ptr.append(comp_ptr[-1] + len(s.compute_nodes))
            msg_ptr.append(msg_ptr[-1])
            msg_barrier.append(0)
        elif s.msgs is not None and len(s.msgs):
            kinds.append(1)
            msgs.append(np.asarray(s.msgs, np.int64).reshape(-1, 3))
            msg_ptr.append(msg_ptr[-1] + len(s.msgs))
            comp_ptr.append(comp_ptr[-1])
            msg_barrier.append(1 if s.barrier else 0)
        elif s.barrier:
            kinds.append(2)
            comp_ptr.append(comp_ptr[-1])
            msg_ptr.append(msg_ptr[-1])
            msg_barrier.append(1)
        else:  # empty step: drop
            continue
    np.savez_compressed(
        path,
        meta=np.array([FORMAT_VERSION], np.int64),
        name=np.array([trace.name]),
        nodes=np.asarray(trace.nodes, np.int64),
        step_kind=np.asarray(kinds, np.uint8),
        comp_ptr=np.asarray(comp_ptr, np.int64),
        comp_node=(np.concatenate(comp_node) if comp_node
                   else np.zeros(0, np.int64)),
        comp_secs=(np.concatenate(comp_secs) if comp_secs
                   else np.zeros(0, np.float64)),
        msg_ptr=np.asarray(msg_ptr, np.int64),
        msgs=(np.concatenate(msgs) if msgs
              else np.zeros((0, 3), np.int64)),
        msg_barrier=np.asarray(msg_barrier, np.uint8),
    )


def load_trace(path) -> Trace:
    z = np.load(path, allow_pickle=False)
    version = int(z["meta"][0])
    if version != FORMAT_VERSION:
        raise ValueError(f"trace format v{version}, expected "
                         f"v{FORMAT_VERSION}")
    t = Trace(nodes=z["nodes"], name=str(z["name"][0]))
    kinds = z["step_kind"]
    cp, mp = z["comp_ptr"], z["msg_ptr"]
    for i, kind in enumerate(kinds):
        if kind == 0:
            t.steps.append(Step(
                compute_nodes=z["comp_node"][cp[i]:cp[i + 1]],
                compute_secs=z["comp_secs"][cp[i]:cp[i + 1]]))
        elif kind == 1:
            t.steps.append(Step(
                msgs=z["msgs"][mp[i]:mp[i + 1]],
                barrier=bool(z["msg_barrier"][i])))
        else:
            t.steps.append(Step(barrier=True))
    return t
