"""Synthetic application trace generators (paper §4 applications).

The original VEF traces are not redistributable; these generators reproduce
the *communication structure* the paper describes for each application and
are tuned so the network-activity signature matches the published timelines
(Fig 6/9/12/15) and inactivity histograms (Fig 1):

* LAMMPS:  startup bcast -> long setup compute -> iterations of {compute,
  P2P halo exchange, AllReduce (dominant), periodic FFT AlltoAll} -> reduce.
* PATMOS:  startup bcast -> one very long independent compute -> final
  AllReduce + Reduce (network touched only at the ends).
* MLWF:    Horovod training: per layer Gather + 2x Broadcast repeated, then
  a large AllReduce per step; near-continuous traffic.
* AlexNet: per-iteration forward compute, then per-layer backprop AllReduce
  bursts with real AlexNet layer parameter sizes; idle between bursts.

Allocations are a subset of the full-system nodes (default: linear mapping
from node 0), matching the paper's setup where the rest of the system idles.
"""
from __future__ import annotations

import numpy as np

from repro.traffic import collectives as C
from repro.traffic.trace import Trace


def allocate(topo, n, mapping="linear", seed=0):
    assert n <= topo.n_nodes
    if mapping == "linear":
        return np.arange(n, dtype=np.int64)
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(topo.n_nodes, n, replace=False)).astype(np.int64)


def lammps(topo, n_nodes=64, iters=40, scale=1.0, mapping="linear"):
    nodes = allocate(topo, n_nodes, mapping)
    t = Trace(nodes=nodes, name="lammps")
    t.rounds(C.broadcast(nodes, 1 << 20))              # model distribution
    t.compute(0.8 * scale)                             # setup (Fig 6: ~1 s)
    for i in range(iters):
        t.compute(20e-3 * scale)
        t.rounds(C.p2p_halo(nodes, 256 << 10))         # ghost-atom exchange
        t.compute(2e-3 * scale)
        t.rounds(C.allreduce(nodes, 64 << 10))         # dominant collective
        if i % 10 == 9:
            t.rounds(C.alltoall(nodes, 512 << 10))     # FFT long-range
    t.rounds(C.reduce(nodes, 1 << 20), barrier_last=True)
    return t


def patmos(topo, n_nodes=64, compute_secs=1285.0, mapping="linear"):
    nodes = allocate(topo, n_nodes, mapping)
    t = Trace(nodes=nodes, name="patmos")
    t.rounds(C.broadcast(nodes, 8 << 20))              # input decks
    t.compute(compute_secs)                            # independent MC batches
    t.rounds(C.allreduce(nodes, 1 << 20))              # global mean
    t.rounds(C.reduce(nodes, 1 << 20), barrier_last=True)   # variance
    return t


def mlwf(topo, n_nodes=64, steps=25, layers=8, mapping="linear"):
    nodes = allocate(topo, n_nodes, mapping)
    t = Trace(nodes=nodes, name="mlwf")
    t.rounds(C.broadcast(nodes, 16 << 20))             # initial weights
    for s in range(steps):
        for _ in range(layers):
            t.compute(1.5e-3)
            t.rounds(C.gather(nodes, 128 << 10))
            t.rounds(C.broadcast(nodes, 128 << 10))
            t.rounds(C.broadcast(nodes, 64 << 10))
        t.compute(30e-3)
        t.rounds(C.allreduce(nodes, 8 << 20))          # gradient exchange
    t.barrier()
    return t


# AlexNet parameter counts per gradient bucket (backprop order), bytes = 4*N
_ALEXNET_LAYERS = [4_097_000, 16_781_312, 37_752_832,
                   884_736, 1_327_104, 884_736, 614_656, 34_944]


def alexnet(topo, n_nodes=64, iters=10, mapping="linear"):
    nodes = allocate(topo, n_nodes, mapping)
    t = Trace(nodes=nodes, name="alexnet")
    t.rounds(C.broadcast(nodes, 244 << 20))            # weights
    for _ in range(iters):
        t.compute(0.5)                                 # forward + loss
        for p in _ALEXNET_LAYERS:
            t.compute(60e-3)                           # layer backward
            t.rounds(C.allreduce(nodes, 4 * p))        # gradient averaging
    t.barrier()
    return t


GENERATORS = {"lammps": lammps, "patmos": patmos, "mlwf": mlwf,
              "alexnet": alexnet}


def small_apps(topo, n_nodes=16):
    """Reduced versions of all four apps (tests / quick benches)."""
    return {
        "lammps": lammps(topo, n_nodes, iters=8),
        "patmos": patmos(topo, n_nodes, compute_secs=20.0),
        "mlwf": mlwf(topo, n_nodes, steps=4, layers=4),
        "alexnet": alexnet(topo, n_nodes, iters=2),
    }
