"""Sharded multi-device sweep engine: the (T, B) replay grid on a Mesh.

The multi-trace executor (``repro.core.replay``) vmaps one per-trace
program over a leading T trace axis with B policy lanes inside — a dense
(T, B) grid on ONE device.  This layer partitions that grid across a
2-D device mesh with axes ``("trace", "lane")``:

* the stacked :class:`~repro.traffic.plan.PlanBatch` arrays shard along
  T (``PartitionSpec("trace")``) — each device holds only its trace
  shard of the plan, so plans never replicate across the mesh;
* per-lane carries (net state, ready clocks, latency accumulators)
  shard along both axes (``P("trace", "lane")``); policy parameters
  shard along lanes only;
* the per-segment program is the SAME ``_make_run`` scan the
  single-device path jits, wrapped in ``shard_map`` — each device runs
  the identical step arithmetic on its (T/dt, B/db) tile, and there is
  no cross-device communication at all (the grid is embarrassingly
  parallel), so results are bit-identical to the vmapped engine and the
  serial oracle (``tests/test_shard_sweep.py``).

T and B rarely divide the mesh evenly: T pads with inert trace rows
(all-False participant mask, no messages, no barriers — provably no-op
steps) and B pads by repeating lane 0; both are sliced off at readback.
Placement (``jax.device_put`` with ``NamedSharding``) is cached per
(batch, mesh) in a small LRU — the device-local plan cache keyed by
(trace, topo) plan identity plus the mesh — so warm sweeps re-dispatch
into resident shards without host->device traffic, and compile counts
stay exactly one program per segment shape (placement itself compiles
nothing; ``baselines/compile_counts.json`` pins warm reruns at 0).

Enable explicitly (``use_mesh(...)`` / ``set_mesh``) or let
``auto_mesh`` pick a mesh whenever >1 device is visible and the grid is
big enough to tile.  ``sweep.sweep_cells`` consults this module, so the
tuner and suite runner go multi-device with no caller changes:

    XLA_FLAGS=--xla_force_host_platform_device_count=8  # or real chips
    with shard_sweep.use_mesh():
        tune_catalog(topo, ...)
"""
from __future__ import annotations

import math
from collections import OrderedDict
from contextlib import contextmanager
from functools import lru_cache, partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import replay
from repro.core.eee import Policy, PowerModel, canonical_proto
from repro.traffic.plan import PlanBatch

SP_TB = P("trace", "lane")
SP_B = P("lane")
SP_T = P("trace")

# ---------------------------------------------------------------------------
# Mesh selection
# ---------------------------------------------------------------------------


def _factor_pairs(n: int):
    for dt in range(1, n + 1):
        if n % dt == 0:
            yield dt, n // dt


def mesh_for(T: int, B: int, devices=None) -> Mesh:
    """Build the ("trace", "lane") mesh that tiles a (T, B) grid with the
    fewest padded cells.  Ties break toward more trace shards (trace rows
    carry the plan arrays, so splitting T first keeps per-device plan
    memory smallest)."""
    devices = jax.devices() if devices is None else list(devices)
    n = len(devices)

    def padded_cells(dt, db):
        return (math.ceil(T / dt) * dt) * (math.ceil(B / db) * db) - T * B

    dt, db = min(_factor_pairs(n),
                 key=lambda p: (padded_cells(*p), p[1]))
    return Mesh(np.asarray(devices).reshape(dt, db), ("trace", "lane"))


_ACTIVE_MESH: Optional[Mesh] = None
_AUTO = False


def set_mesh(mesh: Optional[Mesh], auto: bool = False) -> None:
    """Install the mesh ``sweep_cells`` dispatches onto (None disables).
    ``auto=True`` (with ``mesh=None``) re-derives a best-fit mesh per
    grid shape from all visible devices."""
    global _ACTIVE_MESH, _AUTO
    _ACTIVE_MESH, _AUTO = mesh, auto


@contextmanager
def use_mesh(mesh: Optional[Mesh] = None):
    """Scoped ``set_mesh``: an explicit mesh, or auto mode when None."""
    prev = (_ACTIVE_MESH, _AUTO)
    set_mesh(mesh, auto=mesh is None)
    try:
        yield
    finally:
        set_mesh(*prev)


def active_mesh(T: int, B: int) -> Optional[Mesh]:
    """The mesh a (T, B) grid should run on right now, or None for the
    single-device path.  Auto mode only engages when sharding can help:
    >1 device and at least one grid cell per device."""
    if _ACTIVE_MESH is not None:
        return _ACTIVE_MESH
    if _AUTO and jax.device_count() > 1 and T * B >= jax.device_count():
        return mesh_for(T, B)
    return None


# ---------------------------------------------------------------------------
# Device-local plan placement (the per-(trace, topo, shard) plan cache)
# ---------------------------------------------------------------------------

# (id(batch), mesh, T_pad) -> (batch strong ref, part_mask, [segment xs])
_PLACED: "OrderedDict[tuple, tuple]" = OrderedDict()
_PLACED_MAX = 16
_PLACED_STATS = {"hits": 0, "misses": 0}


def _pad_T(v: jnp.ndarray, T_pad: int, fill=0):
    extra = T_pad - v.shape[0]
    if extra <= 0:
        return v
    pad = jnp.full((extra,) + v.shape[1:], fill, v.dtype)
    return jnp.concatenate([v, pad])


def _place_batch(batch: PlanBatch, mesh: Mesh, T_pad: int):
    """Shard ``batch``'s arrays along the trace axis, padding T with inert
    rows (no participants, no messages, no barriers — every padded step
    lowers to the executor's cond-false / no-op branches).  Cached per
    (batch, mesh): each device keeps only its own trace shard resident,
    and warm sweeps skip the host->device placement entirely."""
    key = (id(batch), mesh, T_pad)
    hit = _PLACED.get(key)
    if hit is not None and hit[0] is batch:
        _PLACED_STATS["hits"] += 1
        _PLACED.move_to_end(key)
        return hit[1], hit[2]
    _PLACED_STATS["misses"] += 1

    def put_T(v, fill=0):
        return jax.device_put(_pad_T(v, T_pad, fill),
                              NamedSharding(mesh, SP_T))

    part_mask = put_T(batch.part_mask)
    seg_xs = [{k: put_T(v, -1 if k == "links" else 0)
               for k, v in seg.xs.items()} for seg in batch.segments]
    _PLACED[key] = (batch, part_mask, seg_xs)
    while len(_PLACED) > _PLACED_MAX:
        _PLACED.popitem(last=False)
    return part_mask, seg_xs


def placement_cache_clear() -> None:
    _PLACED.clear()
    for k in _PLACED_STATS:
        _PLACED_STATS[k] = 0


def placement_cache_info() -> dict:
    return {"placed": len(_PLACED), **_PLACED_STATS}


# ---------------------------------------------------------------------------
# The sharded per-segment runner
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _sharded_runner(proto: Policy, pm: PowerModel, n_links: int, cap: int,
                    mesh: Mesh, mode: str = "scan",
                    needs_sort: bool = True):
    """``_make_run`` vmapped over T and shard_mapped over the mesh — the
    same scan program as ``replay._multi_segment_runner``, tiled.  Every
    input/output is tile-local (``check_rep=False``: there is no
    replication to verify and no collective in the program).  Carry
    buffers donate, exactly like the single-device runners."""
    run = replay._make_run(proto, pm, n_links, cap, collect_events=False,
                           mode=mode, needs_sort=needs_sort)
    vrun = jax.vmap(run, in_axes=(0, None, 0, 0, 0, 0, 0))
    sm = shard_map(vrun, mesh=mesh,
                   in_specs=(SP_TB, SP_B, SP_TB, SP_TB, SP_TB, SP_T, SP_T),
                   out_specs=(SP_TB, None), check_rep=False)
    return partial(jax.jit, donate_argnums=(0, 2, 3, 4))(sm)


def _pad_pols(pols: List[Policy], B_pad: int) -> List[Policy]:
    return list(pols) + [pols[0]] * (B_pad - len(pols))


def replay_plans_sharded(batch: PlanBatch, pols, pm: PowerModel,
                         mesh: Optional[Mesh] = None):
    """Sharded twin of :func:`repro.core.replay.replay_plans` — same
    signature plus ``mesh``, same ``(nets, t_end, lat_sum, lat_max)``
    return contract, bit-identical per-cell results.

    Falls back to the single-device engine when the mesh is trivial
    (1 device) so callers can pass whatever ``active_mesh`` returned.
    """
    T, B = batch.n_traces, len(pols)
    if mesh is None:
        mesh = active_mesh(T, B)
    if mesh is None or mesh.devices.size <= 1:
        return replay.replay_plans(batch, pols, pm)

    dt, db = mesh.shape["trace"], mesh.shape["lane"]
    T_pad = math.ceil(T / dt) * dt
    B_pad = math.ceil(B / db) * db

    proto = canonical_proto(pols[0])
    params = replay.stack_params(_pad_pols(pols, B_pad))
    carry = replay._multi_init(proto, batch.n_links, batch.n_nodes,
                               T_pad)(params)

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    params = jax.tree.map(lambda x: put(x, SP_B), params)
    carry = (jax.tree.map(lambda x: put(x, SP_TB), carry[0]),
             put(carry[1], SP_TB), put(carry[2], SP_TB),
             put(carry[3], SP_TB))
    part_mask, seg_xs = _place_batch(batch, mesh, T_pad)

    for seg, xs in zip(batch.segments, seg_xs):
        md, ns = replay._seg_flags(seg, proto)
        run = _sharded_runner(proto, pm, batch.n_links, seg.cap, mesh,
                              md, ns)
        carry, _ = run(carry[0], params, carry[1], carry[2], carry[3],
                       part_mask, xs)
    nets, ready, lat_sum, lat_max = carry

    t_end = np.asarray(replay._participant_max_multi(part_mask, ready))
    t_end = np.where(batch.has_participants[:, None], t_end[:T, :B], 0.0)
    nets = jax.tree.map(lambda x: x[:T, :B], nets)
    return (nets, t_end, np.asarray(lat_sum)[:T, :B],
            np.asarray(lat_max)[:T, :B])
