"""Fault tolerance and straggler mitigation (library layer).

On a 1000-node fleet these hooks sit between the cluster scheduler and the
training loop; everything here is deterministic and unit-testable on one
host — failures and step timings are injected, never sampled from real
hardware.  Three pieces:

* ``StragglerMonitor`` — EWMA per-worker step times; flags workers slower
  than ``threshold`` x the fleet median and proposes shard reassignment
  (slowest worker swaps data shard with the fastest, bounded frequency).
* ``plan_elastic_mesh`` — given a surviving device count, pick the largest
  usable (data, model) mesh shape that preserves the model-parallel degree
  (TP degree is baked into compiled weights layouts; DP shrinks freely).
* ``run_with_recovery`` — drives step functions under injected failures:
  on failure, restore from the newest checkpoint and replay.  Exercises the
  checkpoint/restart invariance the data pipeline guarantees.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------


@dataclass
class StragglerReport:
    step: int
    stragglers: List[int]
    median: float
    per_worker: Dict[int, float]
    reassignment: Optional[tuple] = None   # (slow_worker, fast_worker)


class StragglerMonitor:
    """EWMA step-time tracking with reassignment proposals.

    ``observe(step, {worker: seconds})`` returns a StragglerReport when any
    worker's smoothed time exceeds ``threshold`` x median; proposals are
    rate-limited to one per ``cooldown`` steps.
    """

    def __init__(self, n_workers: int, threshold: float = 1.5,
                 alpha: float = 0.3, cooldown: int = 20, warmup: int = 3):
        self.n = n_workers
        self.threshold = threshold
        self.alpha = alpha
        self.cooldown = cooldown
        self.warmup = warmup
        self.ewma = np.zeros(n_workers)
        self.count = np.zeros(n_workers, np.int64)
        self.last_action = -10**9
        self.history: List[StragglerReport] = []

    def observe(self, step: int, times: Dict[int, float]):
        for w, t in times.items():
            if self.count[w] == 0:
                self.ewma[w] = t
            else:
                self.ewma[w] = (1 - self.alpha) * self.ewma[w] + self.alpha * t
            self.count[w] += 1
        ready = self.count >= self.warmup
        if not ready.any():
            return None
        med = float(np.median(self.ewma[ready]))
        slow = [int(w) for w in np.nonzero(
            ready & (self.ewma > self.threshold * med))[0]]
        if not slow:
            return None
        report = StragglerReport(
            step=step, stragglers=slow, median=med,
            per_worker={int(w): float(self.ewma[w])
                        for w in range(self.n) if ready[w]})
        if step - self.last_action >= self.cooldown:
            worst = int(max(slow, key=lambda w: self.ewma[w]))
            fastest = int(np.argmin(np.where(ready, self.ewma, np.inf)))
            if fastest != worst:
                report.reassignment = (worst, fastest)
                self.last_action = step
        self.history.append(report)
        return report


# ---------------------------------------------------------------------------
# Elastic mesh planning
# ---------------------------------------------------------------------------


def plan_elastic_mesh(n_devices: int, model_degree: int,
                      min_data: int = 1) -> tuple:
    """Largest (data, model) shape with the same TP degree that fits in
    ``n_devices``.  Returns (data, model) — data is the free axis.

    A TP-degree change forces a weight-layout reshard (still possible via
    the topology-independent checkpoint, but slower), so elasticity keeps
    TP fixed and shrinks/grows DP, the standard production policy.
    """
    if model_degree <= 0:
        raise ValueError("model_degree must be positive")
    data = n_devices // model_degree
    if data < min_data:
        raise ValueError(
            f"{n_devices} devices cannot host model_degree={model_degree}")
    return (data, model_degree)


# ---------------------------------------------------------------------------
# Failure injection + recovery driver
# ---------------------------------------------------------------------------


class WorkerFailure(RuntimeError):
    def __init__(self, step, worker):
        super().__init__(f"worker {worker} failed at step {step}")
        self.step = step
        self.worker = worker


@dataclass
class RecoveryStats:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    wasted_steps: int = 0          # recomputed after restart
    reassignments: int = 0
    log: list = field(default_factory=list)


def run_with_recovery(step_fn: Callable, state, ckpt, n_steps: int, *,
                      start_step: int = 0,
                      fail_at: Dict[int, int] | None = None,
                      monitor: StragglerMonitor | None = None,
                      timings_fn: Callable | None = None,
                      save_every: int = 10,
                      metadata_fn: Callable | None = None) -> tuple:
    """Run ``state = step_fn(state, step)`` for ``n_steps`` with checkpoint/
    restart.  ``fail_at``: {step: worker} injected failures (each fires
    once).  Returns (state, RecoveryStats).
    """
    fail_at = dict(fail_at or {})
    stats = RecoveryStats()
    step = start_step
    last_saved = None
    # initial checkpoint so step-0 failures are recoverable
    ckpt.save(state, step, (metadata_fn or (lambda s: {}))(step))
    last_saved = step

    while step < start_step + n_steps:
        try:
            if step in fail_at:
                worker = fail_at.pop(step)
                raise WorkerFailure(step, worker)
            state = step_fn(state, step)
            stats.steps_run += 1
            if timings_fn and monitor:
                rep = monitor.observe(step, timings_fn(step))
                if rep and rep.reassignment:
                    stats.reassignments += 1
                    stats.log.append(("reassign", step, rep.reassignment))
            step += 1
            if (step - start_step) % save_every == 0:
                ckpt.save_async(state, step,
                                (metadata_fn or (lambda s: {}))(step))
                last_saved = step
        except WorkerFailure as e:
            stats.failures += 1
            stats.log.append(("failure", e.step, e.worker))
            ckpt.wait()
            state, restored_step, _ = ckpt.restore(state)
            stats.restores += 1
            stats.wasted_steps += step - restored_step
            stats.log.append(("restore", restored_step))
            step = restored_step
    ckpt.wait()
    return state, stats
