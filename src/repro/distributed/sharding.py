"""Sharding rules: parameter, activation, batch, and cache PartitionSpecs.

Mesh axes: optional ``pod`` (cross-pod data parallelism), ``data`` (DP),
``model`` (TP for attention heads / MLP hidden / vocab, EP for MoE experts,
SP for long-context KV).  Rules are path-pattern based so every arch family
shares one table.

Uneven dims (e.g. 56 heads / 16-way TP) rely on GSPMD padding; KV heads
smaller than the TP degree stay replicated (Megatron GQA convention) by
sharding the *folded* head*dim axis of the projections instead.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# (regex on 'a/b/c' param path) -> spec for the *unstacked* leaf;
# stacked block leaves get None prepended automatically.
_PARAM_RULES = [
    (r"embed$", P("model", None)),
    (r"lm_head$", P(None, "model")),
    (r"pos_embed_(dec|enc)$", P("model", None)),
    # attention
    (r"(attn|cross)/w[qkv]$", P(None, "model")),
    (r"(attn|cross)/wo$", P("model", None)),
    (r"(attn|cross)/b[qkv]$", P("model")),
    (r"(attn|cross)/(q|k)_norm$", P(None)),
    # dense mlp
    (r"mlp/w[13]$", P(None, "model")),
    (r"mlp/w2$", P("model", None)),
    # moe: experts across 'model' (EP)
    (r"moe/router$", P(None, None)),
    (r"moe/we[13]$", P("model", None, None)),
    (r"moe/we2$", P("model", None, None)),
    # mamba2
    (r"mamba/in_proj$", P(None, "model")),
    (r"mamba/conv_w$", P(None, "model")),
    (r"mamba/conv_b$", P("model")),
    (r"mamba/(A_log|D|dt_bias)$", P(None)),
    (r"mamba/ssm_norm$", P("model")),
    (r"mamba/out_proj$", P("model", None)),
    # rwkv6
    (r"tm/w_[rkvg]$", P(None, "model")),
    (r"tm/w_o$", P("model", None)),
    (r"tm/w[ab0]$", P(None)),
    (r"tm/(mu_.|u|ln_x)$", P(None)),
    (r"cm/w_[kr]$", P(None, "model")),
    (r"cm/w_v$", P("model", None)),
    (r"cm/mu_.$", P(None)),
    # norms and anything else small
    (r".*", P(None)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_spec_tree(params_shape, mesh: Mesh):
    """PartitionSpec pytree for a param pytree (shapes or arrays)."""

    def one(path, leaf):
        ps = _path_str(path)
        stacked = "blocks" in ps  # scanned layers: leading L dim
        for pat, spec in _PARAM_RULES:
            if re.search(pat, ps):
                tup = tuple(spec)
                if stacked:
                    tup = (None,) + tup
                # trim/extend to the leaf rank
                rank = len(leaf.shape)
                tup = tuple(tup[:rank]) + (None,) * max(0, rank - len(tup))
                # drop axes that do not divide AND are not GSPMD-paddable?
                # GSPMD pads any uneven dim; keep as-is.
                return P(*tup)
        return P()

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_spec_tree(params_shape, mesh))


# ---------------------------------------------------------------------------
# Batch / activation / cache specs
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    """Shard batch over (pod, data) when divisible, else replicate batch."""
    dp = dp_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in dp]))
    if global_batch % size == 0:
        return P(dp)
    if global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P()


def batch_shardings(batch_shape_tree, mesh: Mesh, global_batch: int):
    bspec = batch_spec(mesh, global_batch)

    def one(leaf):
        rank = len(leaf.shape)
        return NamedSharding(mesh, P(*(tuple(bspec) + (None,) * (rank - 1))))

    return jax.tree.map(one, batch_shape_tree)


def cache_spec_tree(cache_shape, mesh: Mesh, global_batch: int):
    """KV/state cache shardings.

    Layout (family-dependent leaves):
      k/v/ck/cv:      (L, B, S, Hkv, dh)  -> shard B over dp, S over model
      k_local/...:    (L, B, W, Hkv, dh)  -> same
      conv:           (L, B, W-1, C)      -> C over model
      ssm:            (L, B, H, N, P)     -> H over model
      wkv:            (L, B, H, dh, dh)   -> H over model
      shift_*:        (L, B, D)           -> D over model
    When B is not divisible by dp (e.g. long_500k, B=1) the batch axis is
    left unsharded and the sequence axis takes ("data","model") instead.
    """
    dp = dp_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in dp]))
    b_ok = global_batch % size == 0
    bax = dp if b_ok else None
    seq_ax = "model" if b_ok else (dp + ("model",)
                                   if "pod" not in mesh.axis_names
                                   else ("data", "model"))

    def one(path, leaf):
        ps = _path_str(path)
        rank = len(leaf.shape)
        if re.search(r"(^|/)(k|v|ck|cv|k_local|v_local|k_global|v_global)$",
                     ps):
            return P(None, bax, seq_ax, None, None)
        if ps.endswith("conv"):
            return P(None, bax, None, "model")
        if ps.endswith("ssm") or ps.endswith("wkv"):
            return P(None, bax, "model", None, None)
        if ps.startswith("shift") or ps.endswith("shift_a") \
                or ps.endswith("shift_f"):
            return P(None, bax, "model")
        return P(*((None,) * rank))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def cache_shardings(cache_shape, mesh: Mesh, global_batch: int):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_spec_tree(cache_shape, mesh, global_batch))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
