"""Mesh-aware activation sharding constraints, usable from model code.

Model code stays mesh-agnostic: ``constrain`` looks up the ambient abstract
mesh (set by ``with mesh:`` in the launcher) and becomes a no-op when there
is none (CPU unit tests) or when a dim does not divide.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _mesh():
    # abstract mesh (jax.set_mesh / use_mesh context)
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    # `with mesh:` (the launcher/dry-run convention) sets the physical
    # mesh on thread_resources, NOT the abstract mesh — check it too,
    # else every activation constraint in model code silently no-ops
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty and m.axis_names:
            return m
    except Exception:
        pass
    return None


def _axis_size(mesh, ax):
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
        return n
    return dict(zip(mesh.axis_names, mesh.axis_sizes))[ax]


def batch_axes(mesh=None):
    mesh = mesh or _mesh()
    if mesh is None:
        return None
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None


def constrain(x, *spec_dims):
    """with_sharding_constraint with symbolic dims:

    'B' -> (pod, data) when divisible; 'S' -> model when divisible;
    'M' -> model when divisible; None -> unsharded.
    """
    mesh = _mesh()
    if mesh is None:
        return x
    names = mesh.axis_names
    dims = []
    for d, size in zip(spec_dims, x.shape):
        if d == "B":
            ax = batch_axes(mesh)
            dims.append(ax if ax and size % _axis_size(mesh, ax) == 0
                        else None)
        elif d in ("S", "M"):
            ok = "model" in names and size % _axis_size(mesh, "model") == 0
            dims.append("model" if ok else None)
        else:
            dims.append(None)
    if all(d is None for d in dims):
        return x
    return jax.lax.with_sharding_constraint(x, P(*dims))
