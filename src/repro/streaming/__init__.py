"""Streaming power advisor: closed-loop policy switching under traffic drift.

The paper's critique of dynamic power-down is that reactive mechanisms are
caught out when the workload drifts; the related EEE literature (Cenedese
et al. arXiv:1503.02843, Rodríguez-Pérez et al. arXiv:1507.07411) shows
the controller must re-evaluate as the arrival process changes.  This
package closes that loop (DESIGN.md §11):

* ``repro.streaming.drift`` — time-varying stochastic scenarios (diurnal
  sine rates, flash-crowd spikes, regime-switching ON-OFF) emitted as a
  sequence of fixed-shape windows sharing ONE compiled plan shape;
* ``repro.streaming.controller`` — the pure hysteresis switching rule
  (min-dwell + margin over smoothed windowed objectives), property-tested
  like ``repro.tuning.frontier``;
* ``repro.streaming.online`` — the online advisor loop: each window is
  lowered to a plan and replayed against the incumbent policy plus a
  tuned challenger pool on the existing ``stack_plans``/``sweep_cells``
  compiled path, with a warm-path guarantee that window re-advice after
  the first window compiles ZERO programs.

Front door: ``launch.power_advisor.advise_stream`` (and the ``--stream``
CLI mode).
"""
from repro.streaming.controller import (ControllerState, SwitchConfig,
                                        decide)
from repro.streaming.drift import (DRIFT_CATALOG, DriftSpec, get_drift,
                                   list_drifts, regime_path, window_rates,
                                   window_trace)
from repro.streaming.online import advise_stream, challenger_pool

__all__ = [
    "ControllerState", "SwitchConfig", "decide",
    "DRIFT_CATALOG", "DriftSpec", "get_drift", "list_drifts",
    "regime_path", "window_rates", "window_trace",
    "advise_stream", "challenger_pool",
]
