"""The online advisor loop: windowed replay + closed-loop policy switching.

``advise_stream`` consumes a :class:`~repro.streaming.drift.DriftSpec`
window by window.  Each window is synthesized (seeded, cacheable),
incrementally lowered to a :class:`~repro.traffic.plan.TracePlan` and
replayed against the incumbent policy, a tuned challenger pool and a
hidden always-on baseline lane in ONE batched pass per static policy
group — the existing ``stack_plans``/``sweep_cells`` compiled path, with
the wavefront executor pinned so the program key is traffic-independent.
The switching controller (``repro.streaming.controller``) then folds the
window's scores into its hysteresis state and picks the next window's
incumbent under the degradation budget.

Warm-path contract (DESIGN.md §11): every window of a stream shares one
plan shape and the pool is fixed, so all programs compile on window 0 and
every later window's re-advice compiles ZERO programs — hard-asserted via
``instrument.compile_guard`` (``warm_guard=True``, the default) and pinned
in ``benchmarks/baselines/compile_counts.json`` (``"stream": 0``).

The loop is strictly causal: window ``w`` is served by the incumbent
chosen after window ``w-1``; the counterfactual lanes (challengers the
controller did NOT deploy) cost vmap width, not extra programs, and feed
both the switching decision and the first regret-style evaluation in the
repo — online vs the best single static policy in hindsight.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional

from repro.core.eee import Policy, PowerModel
from repro.core.instrument import compile_guard, count_compiles
from repro.core.replay import wavefront_mode
from repro.core.simulator import relative_rows, unused_key
from repro.core.sweep import sweep_cells
from repro.scenarios.registry import list_scenarios
from repro.streaming.controller import ControllerState, SwitchConfig, decide
from repro.streaming.drift import DriftSpec, window_rates, window_trace
from repro.tuning import OBJECTIVES

BASELINE = "baseline"


def challenger_pool(topo, *, family: str = "dc", n_nodes: int = 16,
                    budget_pct: float = 1.0, pool_size: int = 6,
                    space=None, rounds: int = 2,
                    objective: str = "link_energy",
                    pm: Optional[PowerModel] = None) -> Dict[str, Policy]:
    """Seed the streaming challenger pool from the auto-tuner.

    Runs ``tuning.tune_scenarios`` over the catalog entries of the drift's
    ``family`` (scaled to the stream's allocation size) and collects, per
    scenario, the budget winner first and then its frontier points by
    ascending energy — the policies that won SOME static workload of the
    family are exactly the candidates worth racing when the live traffic
    drifts between those workloads' regimes.  Deduped by candidate name,
    capped at ``pool_size``; insertion order ranks priors (the first entry
    seeds the stream's initial incumbent).
    """
    from repro.tuning import tiny_space, tune_scenarios
    names = list_scenarios(family)
    assert names, f"no catalog scenarios in family {family!r}"
    report = tune_scenarios(topo, names, budget_pct=budget_pct,
                            rounds=rounds,
                            space=space if space is not None
                            else tiny_space(),
                            n_nodes=n_nodes, objective=objective, pm=pm)
    pool: Dict[str, Policy] = {}
    for tuning in report.scenarios.values():
        order = [tuning.winner] + sorted(
            tuning.frontier, key=lambda p: (p.energy, p.name))
        for p in order:
            if p.policy is not None and p.name not in pool:
                pool[p.name] = p.policy
    assert pool, "tuner returned only the always-on baseline — nothing " \
                 "to race; widen the space or loosen the budget"
    return dict(list(pool.items())[:pool_size])


def _window_scores(rows: dict, objective: str) -> Dict[str, tuple]:
    return {name: (row["exec_overhead_pct"], row[objective])
            for name, row in rows.items()}


def advise_stream(spec: DriftSpec, topo, *,
                  pool: Optional[Dict[str, Policy]] = None,
                  budget_pct: float = 1.0, margin_pct: float = 5.0,
                  min_dwell: int = 2, smooth: float = 0.5,
                  objective: str = "link_energy",
                  pm: Optional[PowerModel] = None,
                  pool_size: int = 6, pool_space=None, pool_rounds: int = 2,
                  wavefront: str = "prefix",
                  warm_guard: bool = True,
                  packing: str = "pow2") -> dict:
    """Run the closed-loop streaming advisor over a drifting stream.

    Returns a report dict:

    * ``timeline`` — one row per advisor window: mean arrival ``rate``,
      the ``incumbent`` that served the window, its ``overhead_pct`` /
      ``energy`` / ``saved_pct`` vs the window's own baseline, whether the
      controller ``switched`` afterwards (and why), and the window's
      backend-compile count (0 after window 0 — the warm-path contract);
    * ``totals`` — stream-level accounting: online energy/overhead vs the
      always-on baseline AND vs the best single static policy in
      hindsight (the lowest-total-energy pool candidate whose TOTAL
      overhead respects the budget), plus the regret-style
      ``gain_vs_static_pct``;
    * ``pool`` / ``controller`` / ``switches`` — the racing lanes, the
      hysteresis config, and the switch count.

    ``pool`` defaults to :func:`challenger_pool` seeded from the drift's
    catalog family; the first pool entry is the initial incumbent (the
    tuned prior).  ``wavefront`` pins the message-phase executor for every
    window replay (the adaptive ``auto`` mode may pick different lowerings
    for windows with different live-message densities, which would break
    the zero-compile warm path; all modes are bit-identical).
    ``warm_guard`` hard-asserts the contract: any window after the first
    that compiles a program raises ``CompileGuardError``.
    """
    assert objective in OBJECTIVES, \
        f"objective {objective!r} not in {OBJECTIVES}"
    pm = pm or PowerModel()
    if pool is None:
        pool = challenger_pool(topo, family=spec.family,
                               n_nodes=spec.n_nodes, budget_pct=budget_pct,
                               pool_size=pool_size, space=pool_space,
                               rounds=pool_rounds, objective=objective,
                               pm=pm)
    assert pool, "empty challenger pool"
    base_key = unused_key(pool)
    lanes = {base_key: Policy(kind="none"), **pool}

    cfg = SwitchConfig(budget_pct=budget_pct, margin_pct=margin_pct,
                       min_dwell=min_dwell, smooth=smooth)
    state = ControllerState(incumbent=next(iter(pool)))
    rates = window_rates(spec).mean(axis=1)

    timeline = []
    totals: Dict[str, dict] = {n: {"energy": 0.0, "makespan": 0.0}
                               for n in (BASELINE, *pool)}
    online = {"energy": 0.0, "makespan": 0.0}
    for w in range(spec.windows):
        trace = window_trace(spec, topo, w)
        guard = (compile_guard(f"stream window {w} re-advice", 0)
                 if warm_guard and w > 0 else count_compiles())
        with guard as cc, wavefront_mode(wavefront):
            wname = trace.name
            res = sweep_cells({wname: trace}, topo, {wname: lanes}, pm,
                              packing=packing)[wname]
        base = res.pop(base_key)
        rows = relative_rows(base, res, BASELINE)

        served = state.incumbent             # chosen before seeing window w
        for name in totals:
            totals[name]["energy"] += rows[name][objective]
            totals[name]["makespan"] += rows[name]["makespan"]
        online["energy"] += rows[served][objective]
        online["makespan"] += rows[served]["makespan"]

        state, switched, reason = decide(
            state, _window_scores(rows, objective), cfg)
        timeline.append({
            "window": w, "rate": float(rates[w]), "incumbent": served,
            "overhead_pct": rows[served]["exec_overhead_pct"],
            "energy": rows[served][objective],
            "saved_pct": 100 * (1 - rows[served][objective]
                                / rows[BASELINE][objective])
            if rows[BASELINE][objective] else 0.0,
            "switched": switched, "reason": reason,
            "next_incumbent": state.incumbent, "compiles": cc.count,
        })

    base_tot = totals[BASELINE]
    def _ovh(t):
        return (100 * (t["makespan"] / base_tot["makespan"] - 1)
                if base_tot["makespan"] else 0.0)
    def _saved(t):
        return (100 * (1 - t["energy"] / base_tot["energy"])
                if base_tot["energy"] else 0.0)
    static_rows = {n: {"energy": t["energy"], "overhead_pct": _ovh(t),
                       "saved_pct": _saved(t)}
                   for n, t in totals.items() if n != BASELINE}
    feasible = {n: r for n, r in static_rows.items()
                if r["overhead_pct"] <= budget_pct}
    # baseline fallback, as everywhere else: a best-static always exists
    best_static = min(feasible, key=lambda n: (feasible[n]["energy"], n)) \
        if feasible else BASELINE
    static_energy = (static_rows[best_static]["energy"] if feasible
                     else base_tot["energy"])
    return {
        "stream": spec.name, "drift": spec.drift, "windows": spec.windows,
        "objective": objective, "budget_pct": budget_pct,
        "pool": list(pool),
        "controller": {"margin_pct": margin_pct, "min_dwell": min_dwell,
                       "smooth": smooth},
        "switches": state.switches,
        "final_incumbent": state.incumbent,
        "timeline": timeline,
        "static_totals": static_rows,
        "totals": {
            "baseline_energy": base_tot["energy"],
            "online_energy": online["energy"],
            "online_overhead_pct": _ovh(online),
            "online_saved_pct": _saved(online),
            "best_static": best_static,
            "best_static_energy": static_energy,
            "best_static_saved_pct": (100 * (1 - static_energy
                                             / base_tot["energy"])
                                      if base_tot["energy"] else 0.0),
            "gain_vs_static_pct": (100 * (1 - online["energy"]
                                          / static_energy)
                                   if static_energy else 0.0),
        },
    }
