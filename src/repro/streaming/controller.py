"""The switching controller: hysteresis over smoothed windowed objectives.

Pure plain-Python decision logic, like ``repro.tuning.frontier`` — no JAX,
no simulation — so the hysteresis rule is directly property-testable
(``tests/test_streaming.py`` drives it with hypothesis): under stationary
scores the incumbent never flaps, the switch count is bounded by the
number of times the (smoothed) winner actually changes, and a switch never
targets a candidate over the degradation budget.

The rule (DESIGN.md §11): per window each candidate's windowed objective
(energy) and degradation are folded into exponential moving averages;
a challenger replaces the incumbent only when

* the incumbent has dwelt at least ``min_dwell`` windows since the last
  switch (hysteresis against regime-boundary chatter), AND
* the best budget-feasible challenger's smoothed energy beats the
  incumbent's by at least ``margin_pct`` percent — or the incumbent
  itself has drifted out of the budget (feasibility overrides the margin:
  staying put would violate the degradation contract).

The always-on baseline lane reports ~0 degradation by construction, so a
feasible fallback always exists — the streaming analogue of
``frontier.budget_winner``'s baseline fallback.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

WindowScores = Dict[str, Tuple[float, float]]   # name -> (degradation%, energy)


@dataclass(frozen=True)
class SwitchConfig:
    """Hysteresis knobs of the streaming advisor."""
    budget_pct: float = 1.0     # max smoothed exec overhead vs baseline, %
    margin_pct: float = 5.0     # challenger must beat incumbent energy by
    min_dwell: int = 2          # windows between switches
    smooth: float = 0.5         # EWMA weight of the newest window (1 = raw)

    def __post_init__(self):
        assert self.budget_pct >= 0 and self.margin_pct >= 0
        assert self.min_dwell >= 1 and 0 < self.smooth <= 1


@dataclass
class ControllerState:
    """Mutable-through-``decide`` controller state (one per stream)."""
    incumbent: str
    dwell: int = 0               # windows since the last switch
    switches: int = 0
    ewma: Dict[str, Tuple[float, float]] = field(default_factory=dict)

    def feasible(self, budget_pct: float) -> Dict[str, float]:
        """{name: smoothed energy} of budget-respecting candidates."""
        return {n: e for n, (d, e) in self.ewma.items() if d <= budget_pct}


def _smooth(state: ControllerState, scores: WindowScores, alpha: float):
    for name, (d, e) in scores.items():
        pd, pe = state.ewma.get(name, (d, e))
        state.ewma[name] = (alpha * d + (1 - alpha) * pd,
                            alpha * e + (1 - alpha) * pe)


def decide(state: ControllerState, scores: WindowScores,
           cfg: SwitchConfig) -> Tuple[ControllerState, bool, str]:
    """Fold one window's scores into ``state`` and decide the NEXT window's
    incumbent.  Returns ``(state, switched, reason)``; ``state`` is the
    same object, updated in place (EWMAs, dwell, switch count).

    ``scores`` maps each candidate (incumbent + challengers + baseline) to
    its ``(degradation_pct, energy)`` on the window just replayed —
    degradation vs the window's own always-on baseline, energy the
    windowed objective (lower is better).
    """
    assert state.incumbent in scores, \
        f"incumbent {state.incumbent!r} missing from window scores"
    _smooth(state, scores, cfg.smooth)
    state.dwell += 1

    feasible = state.feasible(cfg.budget_pct)
    inc_d, inc_e = state.ewma[state.incumbent]
    inc_feasible = state.incumbent in feasible
    if not feasible or state.dwell < cfg.min_dwell:
        return state, False, "dwell" if feasible else "no-feasible"

    best = min(feasible, key=lambda n: (feasible[n], n))
    if best == state.incumbent:
        return state, False, "incumbent-best"
    if inc_feasible and feasible[best] > inc_e * (1 - cfg.margin_pct / 100):
        return state, False, "margin"

    reason = "over-budget" if not inc_feasible else "margin-beaten"
    state.incumbent = best
    state.dwell = 0
    state.switches += 1
    return state, True, reason
