"""Drifting arrival synthesis: time-varying stochastic scenario variants.

A :class:`DriftSpec` describes a STREAM — a sequence of ``windows`` advisor
windows, each ``steps`` service sub-windows of ``window_secs`` — whose
arrival rate drifts over time:

* ``diurnal``  — a sine-modulated Poisson rate (the day/night serving
  cycle that invalidates yesterday's thresholds);
* ``flash``    — a base trickle with multiplicative flash-crowd spikes at
  seeded random times;
* ``regimes``  — a two-state Markov chain over (quiet, busy) rates that
  switches at window boundaries, the regime-switching ON-OFF process of
  the EEE prediction literature (arXiv:1503.02843).

Every window lowers to the SAME compiled plan shape by construction —
the dc-* invariant extended over time: per sub-window exactly one compute
step (seeded jitter) and one message step whose flow count is clipped to
``[2, max_flows]`` with ``max_flows <= 64`` (one message bucket; the floor
of 2 keeps the executor's ``needs_sort`` flag, and with it the program
key, traffic-independent).  The streaming advisor therefore replays every
window of a stream — and every policy lane — through ONE compiled program
per static policy group (``plan.stack_plans`` / ``sweep.sweep_cells``),
compiling only on the first window.

All sampling happens at synthesis time on counter-based Philox streams
derived from ``(seed, window)``, so any window can be re-synthesized
bit-identically without replaying the stream prefix — the warm-path and
oracle (best-static-in-hindsight) evaluations depend on that.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.scenarios.spec import params_of
from repro.scenarios.stochastic import _flow_sizes, _pairs
from repro.traffic.generators import allocate
from repro.traffic.trace import Trace

DRIFT_KINDS = ("diurnal", "flash", "regimes")

# Philox stream tags: rate/regime path vs per-window flow sampling.
_TAG_PATH = 0xD21F7
_TAG_WINDOW = 0x51A7E


def _rng(*key) -> np.random.Generator:
    """Counter-based Philox keyed on an int tuple (via SeedSequence) —
    platform-stable, and independent per (seed, window) so any window
    re-synthesizes bit-identically without replaying the stream prefix."""
    return np.random.Generator(np.random.Philox([int(k) for k in key]))


@dataclass(frozen=True)
class DriftSpec:
    """One named drifting workload stream (a drift-catalog entry).

    ``windows`` advisor windows x ``steps`` service sub-windows; the
    switching controller makes one decision per window.  ``params`` holds
    the drift-kind knobs as sorted (key, value) pairs (``params_of``).
    """
    name: str
    drift: str                    # diurnal | flash | regimes
    n_nodes: int = 16
    seed: int = 0
    windows: int = 24             # advisor windows (controller decisions)
    steps: int = 8                # service sub-windows per advisor window
    window_secs: float = 5e-3     # compute advance per sub-window
    mean_bytes: int = 32 << 10
    max_flows: int = 64           # one-bucket plan-shape guarantee
    jitter: float = 0.5
    mapping: str = "linear"
    family: str = "dc"            # catalog family the challenger pool taps
    params: tuple = ()            # drift knobs, see params_of
    description: str = ""

    def __post_init__(self):
        if self.drift not in DRIFT_KINDS:
            raise ValueError(f"drift kind {self.drift!r} not in "
                             f"{DRIFT_KINDS}")
        if self.n_nodes < 2 or self.windows < 1 or self.steps < 1:
            raise ValueError(f"degenerate drift spec: n_nodes="
                             f"{self.n_nodes} windows={self.windows} "
                             f"steps={self.steps}")
        if not 2 <= self.max_flows <= 64:
            raise ValueError(f"max_flows must be in [2, 64] (one message "
                             f"bucket), got {self.max_flows}")

    def opt(self, key: str, default):
        return dict(self.params).get(key, default)

    def scaled(self, n_nodes: int | None = None, windows: int | None = None,
               seed: int | None = None) -> "DriftSpec":
        """The same stream on a different allocation / length / seed."""
        return dataclasses.replace(
            self,
            n_nodes=self.n_nodes if n_nodes is None else n_nodes,
            windows=self.windows if windows is None else windows,
            seed=self.seed if seed is None else seed)


# ---------------------------------------------------------------------------
# Rate paths
# ---------------------------------------------------------------------------


def _rates_diurnal(spec: DriftSpec) -> np.ndarray:
    base = spec.opt("base_rate", 2000.0)
    amp = spec.opt("amp", 0.9)
    period = spec.opt("period", 12.0)          # in advisor windows
    g = np.arange(spec.windows * spec.steps, dtype=np.float64)
    phase = 2 * np.pi * g / (period * spec.steps)
    # open at the trough: the stream starts in the quiet night phase
    rate = base * (1 + amp * np.sin(phase - np.pi / 2))
    return np.maximum(rate, spec.opt("floor", 1.0))


def _rates_flash(spec: DriftSpec) -> np.ndarray:
    base = spec.opt("base_rate", 400.0)
    mult = spec.opt("spike_mult", 12.0)
    spike_every = spec.opt("spike_every", 6.0)  # mean windows between spikes
    spike_len = int(spec.opt("spike_len", spec.steps))   # sub-windows
    n = spec.windows * spec.steps
    r = _rng(spec.seed, _TAG_PATH)
    p = 1.0 / max(spike_every * spec.steps, 1.0)
    starts = r.random(n) < p
    spike = np.zeros(n, bool)
    for i in np.nonzero(starts)[0]:
        spike[i:i + spike_len] = True
    return np.where(spike, base * mult, base)


def _rates_regimes(spec: DriftSpec) -> np.ndarray:
    lo = spec.opt("rate_lo", 120.0)
    hi = spec.opt("rate_hi", 6000.0)
    path = regime_path(spec)
    per_window = np.where(path, hi, lo)
    return np.repeat(per_window, spec.steps).astype(np.float64)


def regime_path(spec: DriftSpec) -> np.ndarray:
    """(windows,) bool busy-regime path of a ``regimes`` drift — aligned to
    advisor-window boundaries, so hysteresis tests can bound the switch
    count by the number of regime changes.  Non-regime drifts report the
    per-window above-median mask (a coarse busy indicator)."""
    if spec.drift != "regimes":
        rates = window_rates(spec).mean(axis=1)
        return rates > np.median(rates)
    p_stay = spec.opt("p_stay", 0.85)
    p_busy0 = spec.opt("p_busy0", 0.0)
    r = _rng(spec.seed, _TAG_PATH)
    path = np.zeros(spec.windows, bool)
    busy = bool(r.random() < p_busy0)
    for w in range(spec.windows):
        path[w] = busy
        busy = bool(r.random() < (p_stay if busy else 1 - p_stay))
    return path


_RATE_FNS = {"diurnal": _rates_diurnal, "flash": _rates_flash,
             "regimes": _rates_regimes}


def window_rates(spec: DriftSpec) -> np.ndarray:
    """(windows, steps) per-sub-window arrival rates (flows/s) — a pure
    deterministic function of the spec, shared by synthesis, the timeline
    report and the drift tests."""
    rates = _RATE_FNS[spec.drift](spec)
    return rates.reshape(spec.windows, spec.steps)


# ---------------------------------------------------------------------------
# Window synthesis
# ---------------------------------------------------------------------------

# (spec, topo, window) -> Trace.  Identity-stable window traces keep the
# per-(trace, topo) plan cache hot: warm stream re-advice hits resident
# device plans and moves zero host bytes.
_WINDOW_CACHE: OrderedDict = OrderedDict()
_WINDOW_CACHE_MAX = 256


def window_trace(spec: DriftSpec, topo, w: int) -> Trace:
    """Synthesize (or fetch the cached) Trace of advisor window ``w``.

    Structure per sub-window: one jittered compute step then one message
    step of ``clip(Poisson(rate x window_secs), 2, max_flows)`` flows
    between uniform src != dst pairs with heavy-tailed sizes; barrier on
    the window's last sub-window (windows end synchronized, so each
    replays from clean clocks exactly like a standalone trace).
    """
    if not 0 <= w < spec.windows:
        raise IndexError(f"window {w} outside stream [0, {spec.windows})")
    key = (spec, topo, w)
    hit = _WINDOW_CACHE.get(key)
    if hit is not None:
        _WINDOW_CACHE.move_to_end(key)
        return hit
    rates = window_rates(spec)[w]
    nodes = allocate(topo, spec.n_nodes, spec.mapping, spec.seed)
    r = _rng(spec.seed, _TAG_WINDOW, w)
    t = Trace(nodes=nodes, name=f"{spec.name}/w{w:04d}")
    for k in range(spec.steps):
        t.compute(r.uniform(1 - spec.jitter, 1 + spec.jitter, spec.n_nodes)
                  * spec.window_secs)
        # floor of 2 live flows: keeps every window's needs_sort flag (and
        # with it the compiled program key) independent of the drawn rates
        m = int(np.clip(r.poisson(rates[k] * spec.window_secs), 2,
                        spec.max_flows))
        src, dst = _pairs(r, nodes, m)
        t.messages(np.stack([src, dst, _flow_sizes(r, m, spec.mean_bytes)],
                            axis=1), barrier=k == spec.steps - 1)
    _WINDOW_CACHE[key] = t
    while len(_WINDOW_CACHE) > _WINDOW_CACHE_MAX:
        _WINDOW_CACHE.popitem(last=False)
    return t


def window_cache_clear() -> None:
    _WINDOW_CACHE.clear()


# ---------------------------------------------------------------------------
# Drift catalog
# ---------------------------------------------------------------------------

_DRIFTS: Dict[str, DriftSpec] = {}


def register_drift(spec: DriftSpec) -> DriftSpec:
    assert spec.name not in _DRIFTS, f"duplicate drift {spec.name!r}"
    _DRIFTS[spec.name] = spec
    return spec


def get_drift(name: str) -> DriftSpec:
    if name not in _DRIFTS:
        raise KeyError(f"unknown drift {name!r}; have {sorted(_DRIFTS)}")
    return _DRIFTS[name]


def list_drifts() -> list:
    return sorted(_DRIFTS)


DRIFT_CATALOG = [
    DriftSpec(
        "drift-dc-diurnal", "diurnal", seed=51,
        params=params_of(base_rate=2200.0, amp=0.95, period=12.0),
        description="day/night sine rate over two full periods: quiet "
                    "troughs reward aggressive sleeping that the busy "
                    "crest punishes"),
    DriftSpec(
        "drift-dc-flash", "flash", seed=52,
        # spike_len=24 sub-windows = 3 advisor windows: flash crowds are
        # SUSTAINED bursts, so hysteresis can ride out windows 2..3 of
        # each burst on the mild policy after paying for window 1
        params=params_of(base_rate=300.0, spike_mult=20.0, spike_every=8.0,
                         spike_len=24.0),
        description="near-idle trickle with seeded multi-window flash-"
                    "crowd bursts — the sudden-invalidation case for "
                    "tuned thresholds"),
    DriftSpec(
        "drift-dc-regimes", "regimes", seed=53,
        params=params_of(rate_lo=120.0, rate_hi=6000.0, p_stay=0.85),
        description="two-state Markov regime switching between a quiet "
                    "trickle and near-saturation bursts, aligned to "
                    "advisor windows"),
]

for _d in DRIFT_CATALOG:
    register_drift(_d)
