"""Three-level fat-tree (k-ary) topology + D-mod-k routing.

The paper's §2.6: BXIv3 supports "Fat-trees and Megafly/Dragonfly+"; the
evaluation uses Megafly, and this module provides the fat-tree alternative
with the same ``routes()`` contract so every policy/benchmark runs on
either (`benchmarks/bench_topology.py` compares them).

Structure (k even, k-port switches):
  * k pods; each pod has k/2 edge + k/2 aggregation switches;
  * each edge switch hosts k/2 nodes -> n_nodes = k^3/4;
  * (k/2)^2 core switches; aggregation switch a of every pod connects to
    core switches [a*(k/2), (a+1)*(k/2)).

Link classes (undirected), giving 3*k^3/4 links total:
  node:  node n <-> its edge switch                      (k^3/4)
  ea:    edge e of pod p <-> aggregation a of pod p      (k^3/4)
  ac:    aggregation (p, a) <-> core c in a's range      (k^3/4)

Routing is deterministic minimal D-mod-k (Zahavi): up-path choices are
selected by destination id modulo the respective fan-out, so any
destination's down-path is unique and contention-free for global
collectives — exactly the property the paper's deterministic Megafly
routing provides.  Hop counts: same edge 2, same pod 4, cross pod 6.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.topology.base import RoutedTopology


@dataclasses.dataclass(frozen=True)
class FatTree(RoutedTopology):
    k: int = 8

    def __post_init__(self):
        assert self.k % 2 == 0, "fat-tree arity must be even"

    # ---- derived sizes ---------------------------------------------------
    @property
    def half(self) -> int:
        return self.k // 2

    @property
    def n_pods(self) -> int:
        return self.k

    @property
    def nodes_per_edge(self) -> int:
        return self.half

    @property
    def nodes_per_pod(self) -> int:
        return self.half * self.half

    @property
    def n_nodes(self) -> int:
        return self.k * self.nodes_per_pod

    @property
    def n_core(self) -> int:
        return self.half * self.half

    @property
    def n_switches(self) -> int:
        return self.k * self.k + self.n_core      # edge+agg per pod + core

    @property
    def n_node_links(self) -> int:
        return self.n_nodes

    @property
    def n_ea_links(self) -> int:
        return self.k * self.half * self.half

    @property
    def n_ac_links(self) -> int:
        return self.k * self.half * self.half

    @property
    def n_links(self) -> int:
        return self.n_node_links + self.n_ea_links + self.n_ac_links

    @property
    def n_ports(self) -> int:
        return 2 * self.n_links

    @property
    def max_hops(self) -> int:
        return 6

    # ---- link ids ----------------------------------------------------------
    def node_link(self, n):
        return np.asarray(n)

    def ea_link(self, pod, edge, agg):
        h = self.half
        return (self.n_node_links
                + (np.asarray(pod) * h + np.asarray(edge)) * h
                + np.asarray(agg))

    def ac_link(self, pod, agg, core):
        """core is a GLOBAL core id in agg's range [agg*h, (agg+1)*h)."""
        h = self.half
        slot = np.asarray(core) - np.asarray(agg) * h
        return (self.n_node_links + self.n_ea_links
                + (np.asarray(pod) * h + np.asarray(agg)) * h + slot)

    # ---- coordinates ---------------------------------------------------------
    def node_pod(self, n):
        return np.asarray(n) // self.nodes_per_pod

    def node_edge(self, n):
        return (np.asarray(n) % self.nodes_per_pod) // self.nodes_per_edge

    # ---- routing ---------------------------------------------------------------
    def routes(self, src, dst):
        """Deterministic minimal D-mod-k.  Same contract as Megafly.routes:
        (links (M, max_hops) int32 -1-padded, dirs, n_hops)."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        M = src.shape[0]
        h = self.half
        links = np.full((M, self.max_hops), -1, np.int64)
        dirs = np.zeros((M, self.max_hops), np.int64)

        ps, pd = self.node_pod(src), self.node_pod(dst)
        es, ed = self.node_edge(src), self.node_edge(dst)
        same = src == dst
        same_edge = (~same) & (ps == pd) & (es == ed)
        intra = (~same) & (ps == pd) & (es != ed)
        inter = ps != pd

        nl_s, nl_d = self.node_link(src), self.node_link(dst)

        links[same_edge, 0] = nl_s[same_edge]
        links[same_edge, 1] = nl_d[same_edge]
        dirs[same_edge, 1] = 1

        # intra pod via aggregation dst % h (D-mod-k on the up choice)
        agg = dst % h
        up = self.ea_link(ps, es, agg)
        dn = self.ea_link(pd, ed, agg)
        for m, arr, d in ((0, nl_s, 0), (1, up, 0), (2, dn, 1), (3, nl_d, 1)):
            links[intra, m] = arr[intra]
            dirs[intra, m] = d

        # inter pod: agg = dst % h; core slot = (dst // h) % h within agg's
        # range — the D-mod-k pair makes the down-path unique per dst
        agg_i = dst % h
        core = agg_i * h + (dst // h) % h
        up1 = self.ea_link(ps, es, agg_i)
        up2 = self.ac_link(ps, agg_i, core)
        dn2 = self.ac_link(pd, agg_i, core)
        dn1 = self.ea_link(pd, ed, agg_i)
        for m, arr, d in ((0, nl_s, 0), (1, up1, 0), (2, up2, 0),
                          (3, dn2, 1), (4, dn1, 1), (5, nl_d, 1)):
            links[inter, m] = arr[inter]
            dirs[inter, m] = d

        n_hops = np.where(same, 0,
                          np.where(same_edge, 2, np.where(intra, 4, 6)))
        return links.astype(np.int32), dirs.astype(np.int32), \
            n_hops.astype(np.int32)

    def hop_distance(self, src, dst):
        return self.routes(np.atleast_1d(src), np.atleast_1d(dst))[2]


def paper_equivalent_fattree() -> FatTree:
    """k=26 fat-tree: 4394 nodes — the closest k-ary match to the paper's
    4160-node Megafly for like-for-like energy comparisons."""
    return FatTree(k=26)


def small_fattree(k: int = 4) -> FatTree:
    return FatTree(k=k)
