"""Shared topology base: cached batched route expansion.

Both topologies (``Megafly``, ``FatTree``) expand minimal deterministic
routes with host-side numpy.  Route expansion is pure — ``routes(src, dst)``
depends only on the (frozen) topology value and the endpoint arrays — so
repeated lookups for an identical (src, dst) batch can be served from a
cache instead of re-deriving link ids.  The trace-plan compiler
(``repro.traffic.plan``) issues ONE batched lookup per trace through this
cache, so the win comes from whole-trace repetition: replanning the same
trace (cache-evicted or rebuilt-but-identical traces, fresh equal topology
instances across benchmark passes), or distinct traces sharing their full
endpoint pattern.

``routes_cached`` keys on a digest of the endpoint arrays and keeps a small
LRU per topology VALUE (frozen dataclasses hash by value, so equal
instances share entries).  Callers must treat returned arrays as immutable
— they are shared across hits.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np

# topology value -> OrderedDict[(digest, n): (links, dirs, n_hops)].
# Keyed by value (not instance identity/weakref): benchmark passes build
# fresh equal topologies and must keep hitting the same cache.  Bounded:
# a handful of distinct topology values exist per process.
_ROUTE_CACHES: OrderedDict = OrderedDict()
_MAX_TOPOLOGIES = 16


class RoutedTopology:
    """Mixin providing a memoized front-end over ``routes()``.

    Subclasses implement ``routes(src, dst) -> (links, dirs, n_hops)`` with
    the (M, max_hops) -1-padded contract; this mixin adds ``routes_cached``
    with identical semantics plus an LRU keyed on the endpoint arrays.
    """

    route_cache_size: int = 128

    def routes(self, src, dst):
        raise NotImplementedError

    def signature(self) -> tuple:
        """Shape signature ``(n_nodes, n_links, max_hops)`` — the part of a
        topology that determines compiled replay-program shapes.  The plan
        compiler copies it into every ``TracePlan`` (via
        ``plan.topo_signature``), and ``plan.plan_shape_key`` compares those
        fields when deciding whether plans stack along the multi-trace
        axis."""
        return (self.n_nodes, self.n_links, self.max_hops)

    def routes_cached(self, src, dst):
        """Memoized ``routes()``.  Returned arrays are shared across cache
        hits — do not mutate them."""
        src = np.ascontiguousarray(src, np.int64)
        dst = np.ascontiguousarray(dst, np.int64)
        key = (hashlib.blake2b(src.tobytes() + b"|" + dst.tobytes(),
                               digest_size=16).digest(), src.shape[0])
        cache = _ROUTE_CACHES.get(self)
        if cache is None:
            cache = _ROUTE_CACHES[self] = OrderedDict()
            while len(_ROUTE_CACHES) > _MAX_TOPOLOGIES:
                _ROUTE_CACHES.popitem(last=False)
        else:
            _ROUTE_CACHES.move_to_end(self)
        hit = cache.get(key)
        if hit is not None:
            cache.move_to_end(key)
            return hit
        out = self.routes(src, dst)
        cache[key] = out
        while len(cache) > self.route_cache_size:
            cache.popitem(last=False)
        return out

    def route_cache_info(self):
        cache = _ROUTE_CACHES.get(self)
        return {"entries": 0 if cache is None else len(cache),
                "capacity": self.route_cache_size}

    def clear_route_cache(self):
        _ROUTE_CACHES.pop(self, None)
