"""Megafly topology + deterministic minimal routing (the paper's scenario).

Paper scenario (§4): 65 groups x 64 nodes = 4160 nodes.  Each group is a
two-level bipartite graph of 16 radix-16 switches: 8 leaves (8 down-links to
nodes, 8 up-links to spines) and 8 spines (8 down-links to leaves, 8 global
ports).  Every pair of groups is connected by exactly one global link
(65 groups x 64 global ports / 2 = 2080 global links).

Link inventory (undirected): 4160 node links + 65*64 leaf-spine links +
2080 global links = 10400 links = 20800 port-ends (matches Table 5).

Routing is deterministic minimal, D-mod-k style: the up-path spine for an
intra-group packet is ``dst % spines``; for inter-group packets the spine is
forced by the unique global link to the destination group.  Hop counts
(links traversed): same-leaf 2, intra-group 4, inter-group 5.

Everything here is host-side numpy — the trace-plan compiler
(``repro.traffic.plan``) expands paths ONCE per (trace, topology) through
``routes_cached`` and feeds the jitted replay as plain arrays.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.topology.base import RoutedTopology


@dataclasses.dataclass(frozen=True)
class Megafly(RoutedTopology):
    n_groups: int = 65
    leaves_per_group: int = 8
    spines_per_group: int = 8
    nodes_per_leaf: int = 8

    # ---- derived sizes ---------------------------------------------------
    @property
    def nodes_per_group(self) -> int:
        return self.leaves_per_group * self.nodes_per_leaf

    @property
    def n_nodes(self) -> int:
        return self.n_groups * self.nodes_per_group

    @property
    def switches_per_group(self) -> int:
        return self.leaves_per_group + self.spines_per_group

    @property
    def n_switches(self) -> int:
        return self.n_groups * self.switches_per_group

    @property
    def radix(self) -> int:
        return self.nodes_per_leaf + self.spines_per_group

    @property
    def n_node_links(self) -> int:
        return self.n_nodes

    @property
    def n_ls_links(self) -> int:  # leaf-spine
        return self.n_groups * self.leaves_per_group * self.spines_per_group

    @property
    def n_global_links(self) -> int:
        return self.n_groups * (self.n_groups - 1) // 2

    @property
    def n_links(self) -> int:
        return self.n_node_links + self.n_ls_links + self.n_global_links

    @property
    def n_ports(self) -> int:  # port-ends, the paper's "links" count
        return 2 * self.n_links

    @property
    def max_hops(self) -> int:
        return 5

    # ---- link ids ---------------------------------------------------------
    def node_link(self, n):
        return np.asarray(n)

    def ls_link(self, g, leaf, spine):
        return (self.n_node_links
                + (np.asarray(g) * self.leaves_per_group + np.asarray(leaf))
                * self.spines_per_group + np.asarray(spine))

    def global_link(self, g, h):
        g, h = np.asarray(g), np.asarray(h)
        lo, hi = np.minimum(g, h), np.maximum(g, h)
        G = self.n_groups
        # index into the upper-triangular pair list
        idx = lo * G - lo * (lo + 1) // 2 + (hi - lo - 1)
        return self.n_node_links + self.n_ls_links + idx

    def peer_port(self, g, h):
        """Global-port index (0..63) used by group g to reach group h."""
        g, h = np.asarray(g), np.asarray(h)
        return np.where(h < g, h, h - 1)

    def global_spine(self, g, h):
        """Spine in group g owning the global link to group h."""
        return self.peer_port(g, h) // self.spines_per_group

    # ---- node coordinates --------------------------------------------------
    def node_group(self, n):
        return np.asarray(n) // self.nodes_per_group

    def node_leaf(self, n):
        return (np.asarray(n) % self.nodes_per_group) // self.nodes_per_leaf

    # ---- routing ------------------------------------------------------------
    def routes(self, src, dst):
        """Vectorized minimal deterministic routing.

        src, dst: int arrays (M,).  Returns (links (M, max_hops) int32 with -1
        padding, n_hops (M,) int32).  Directions are implicit: direction bit =
        position parity is NOT valid here, so we also return dirs (M, max_hops)
        in {0,1}: 0 = lower-id endpoint transmits, 1 = higher-id endpoint.
        For power accounting only the link id matters; for serialization we
        track per-direction occupancy = 2*link + dir.
        """
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        M = src.shape[0]
        H = self.max_hops
        links = np.full((M, H), -1, np.int64)
        dirs = np.zeros((M, H), np.int64)

        gs, gd = self.node_group(src), self.node_group(dst)
        ls, ld = self.node_leaf(src), self.node_leaf(dst)
        same = src == dst
        same_leaf = (~same) & (gs == gd) & (ls == ld)
        intra = (~same) & (gs == gd) & (ls != ld)
        inter = gs != gd

        nl_s = self.node_link(src)      # node -> leaf (up: dir 0)
        nl_d = self.node_link(dst)      # leaf -> node (down: dir 1)

        # same leaf: [src->leaf, leaf->dst]
        links[same_leaf, 0] = nl_s[same_leaf]
        links[same_leaf, 1] = nl_d[same_leaf]
        dirs[same_leaf, 0] = 0
        dirs[same_leaf, 1] = 1

        # intra group: spine by D-mod-k on destination node id
        sp = dst % self.spines_per_group
        up = self.ls_link(gs, ls, sp)
        dn = self.ls_link(gd, ld, sp)
        for (m, arr, d) in ((0, nl_s, 0), (1, up, 0), (2, dn, 1), (3, nl_d, 1)):
            links[intra, m] = arr[intra]
            dirs[intra, m] = d

        # inter group: forced spine on both sides of the global link
        sp_s = self.global_spine(gs, gd)
        sp_d = self.global_spine(gd, gs)
        up_i = self.ls_link(gs, ls, sp_s)
        gl = self.global_link(gs, gd)
        gdir = np.where(gs < gd, 0, 1)
        dn_i = self.ls_link(gd, ld, sp_d)
        for (m, arr, d) in ((0, nl_s, 0), (1, up_i, 0), (2, gl, None),
                            (3, dn_i, 1), (4, nl_d, 1)):
            links[inter, m] = arr[inter]
            dirs[inter, m] = gdir[inter] if d is None else d

        n_hops = np.where(same, 0,
                          np.where(same_leaf, 2, np.where(intra, 4, 5)))
        return links.astype(np.int32), dirs.astype(np.int32), \
            n_hops.astype(np.int32)

    def hop_distance(self, src, dst):
        return self.routes(np.atleast_1d(src), np.atleast_1d(dst))[2]


def paper_topology() -> Megafly:
    """The exact §4 scenario: 4160 nodes, 1040 switches, 20800 port-ends."""
    return Megafly()


def small_topology(n_groups=5, leaves=4, spines=4, nodes_per_leaf=4) -> Megafly:
    """A reduced Megafly for tests/benchmarks (same structure)."""
    return Megafly(n_groups=n_groups, leaves_per_group=leaves,
                   spines_per_group=spines, nodes_per_leaf=nodes_per_leaf)
