"""Pallas TPU kernel: decoupled per-port EEE/PDT energy replay.

This is the TPU-native rethink of the paper's per-port state machine (see
DESIGN.md §3): all ports march through their event streams in lockstep, one
(gap, duration) pair per step, with the EEE wake/sleep bookkeeping expressed
as vector selects.  Exact for energy/hit/miss statistics given fixed arrival
times (no latency feedback); the coupled `lax.scan` simulator quantifies the
difference.

Dual-mode ladder (DESIGN.md §6): a gap past ``tpdt + t_dst`` demotes the
port to the deep row (t_w2/t_s2) — the extra down transition integrates at
wake power, the span between transitions at the row-1 floor and the
remainder at the row-2 floor.  ``t_dst = inf`` (the single-state lowering)
keeps every row-2 select on its row-1 value, so classic policies are
bit-identical to the pre-ladder kernel.

Ports along lanes (TILE_P=128); events along a fori loop over rows of the
transposed (E, P) input.  VMEM: gaps+durs (E x 128 f32) * 2 = 2 MB at E=2048.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

TILE_P = 128
MAX_E = 8192


def _kernel(gaps_ref, durs_ref, tpdt_ref, tds_ref, hold_ref, tail_ref,
            wake_ref, sleep_ref, sleep2_ref, nwake_ref, hits_ref, miss_ref,
            ndeep_ref, *, t_w, t_s, t_w2, t_s2, n_events):
    tpdt = tpdt_ref[...]
    # per-port demotion timer, pre-clamped to >= t_s by the caller
    # (demotion cannot precede the first down transition)
    tds = tds_ref[...]
    # predictive row: hold-at-source deferral granted to frames that find
    # the port asleep — the effective gap stretches by ``hold`` (0 = off)
    hold = hold_ref[...]

    def body(e, carry):
        wake, sleep, sleep2, nw, hit, miss, nd = carry
        g = gaps_ref[e, :]
        d = durs_ref[e, :]
        act = d > 0
        asleep = act & (g >= tpdt)
        ge = g + jnp.where(asleep, hold, 0.0)
        deep = act & (ge >= tpdt + tds)
        wake_fast = tpdt + t_s + t_w + d
        wake_deep = tpdt + t_s + t_s2 + t_w2 + d
        wake_add = jnp.where(asleep,
                             jnp.where(deep, wake_deep, wake_fast), g + d)
        sleep_add = jnp.where(asleep,
                              jnp.where(deep, tds - t_s,
                                        jnp.maximum(ge - tpdt - t_s, 0.0)),
                              0.0)
        sleep2_add = jnp.where(
            deep, jnp.maximum(ge - tpdt - tds - t_s2, 0.0), 0.0)
        af = asleep.astype(jnp.float32)
        return (wake + jnp.where(act, wake_add, 0.0),
                sleep + jnp.where(act, sleep_add, 0.0),
                sleep2 + sleep2_add,
                nw + af, hit + (act & ~asleep).astype(jnp.float32), miss + af,
                nd + deep.astype(jnp.float32))

    z = jnp.zeros((gaps_ref.shape[1],), jnp.float32)
    wake, sleep, sleep2, nw, hit, miss, nd = lax.fori_loop(
        0, n_events, body, (z, z, z, z, z, z, z))
    tail = tail_ref[...]
    tail_sleeps = tail >= tpdt + t_s
    tail_deep = tail >= tpdt + tds + t_s2
    wake_ref[...] = wake + jnp.where(
        tail_sleeps, tpdt + t_s + jnp.where(tail_deep, t_s2, 0.0), tail)
    sleep_ref[...] = sleep + jnp.where(
        tail_sleeps, jnp.where(tail_deep, tds - t_s, tail - tpdt - t_s), 0.0)
    sleep2_ref[...] = sleep2 + jnp.where(
        tail_deep, tail - tpdt - tds - t_s2, 0.0)
    nwake_ref[...] = nw
    hits_ref[...] = hit
    miss_ref[...] = miss
    ndeep_ref[...] = nd


def port_energy_pallas(gaps, durs, tpdt, tail, *, t_w, t_s,
                       t_w2=0.0, t_s2=0.0, t_dst=None, hold=None,
                       interpret=False):
    """gaps/durs: (E, P) f32; tpdt/tail: (P,) f32; t_dst: scalar or (P,)
    demotion timer (traced — a timer sweep reuses ONE compiled kernel;
    None/inf = single-state).  ``hold``: scalar or (P,) hold-at-source
    deferral (the precoalesce row; traced, None/0 = off).
    Returns dict of (P,)."""
    E, P = gaps.shape
    assert E <= MAX_E, f"E={E} exceeds kernel cap; chunk at ops level"
    Pp = pl.cdiv(P, TILE_P) * TILE_P
    if t_dst is None:
        t_dst = jnp.inf
    if hold is None:
        hold = 0.0
    tds = jnp.broadcast_to(
        jnp.maximum(jnp.asarray(t_dst, jnp.float32), jnp.float32(t_s)), (P,))
    hld = jnp.broadcast_to(jnp.asarray(hold, jnp.float32), (P,))

    def padE(x):
        return jnp.zeros((E, Pp), jnp.float32).at[:, :P].set(
            x.astype(jnp.float32))

    def padP(x, fill=0.0):
        return jnp.full((Pp,), fill, jnp.float32).at[:P].set(
            x.astype(jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_kernel, t_w=float(t_w), t_s=float(t_s),
                          t_w2=float(t_w2), t_s2=float(t_s2), n_events=E),
        grid=(Pp // TILE_P,),
        in_specs=[pl.BlockSpec((E, TILE_P), lambda i: (0, i)),
                  pl.BlockSpec((E, TILE_P), lambda i: (0, i)),
                  pl.BlockSpec((TILE_P,), lambda i: (i,)),
                  pl.BlockSpec((TILE_P,), lambda i: (i,)),
                  pl.BlockSpec((TILE_P,), lambda i: (i,)),
                  pl.BlockSpec((TILE_P,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((TILE_P,), lambda i: (i,))] * 7,
        out_shape=[jax.ShapeDtypeStruct((Pp,), jnp.float32)] * 7,
        interpret=interpret,
    )(padE(gaps), padE(durs), padP(tpdt, fill=1e30),
      padP(tds, fill=float("inf")), padP(hld), padP(tail))
    keys = ["time_wake", "time_sleep", "time_sleep2", "n_wake", "hits",
            "misses", "n_deep"]
    return {k: v[:P] for k, v in zip(keys, outs)}
