"""Pallas TPU kernel: decoupled per-port EEE/PDT energy replay.

This is the TPU-native rethink of the paper's per-port state machine (see
DESIGN.md §3): all ports march through their event streams in lockstep, one
(gap, duration) pair per step, with the EEE wake/sleep bookkeeping expressed
as vector selects.  Exact for energy/hit/miss statistics given fixed arrival
times (no latency feedback); the coupled `lax.scan` simulator quantifies the
difference.

Ports along lanes (TILE_P=128); events along a fori loop over rows of the
transposed (E, P) input.  VMEM: gaps+durs (E x 128 f32) * 2 = 2 MB at E=2048.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

TILE_P = 128
MAX_E = 8192


def _kernel(gaps_ref, durs_ref, tpdt_ref, tail_ref,
            wake_ref, sleep_ref, nwake_ref, hits_ref, miss_ref, *,
            t_w, t_s, n_events):
    tpdt = tpdt_ref[...]

    def body(e, carry):
        wake, sleep, nw, hit, miss = carry
        g = gaps_ref[e, :]
        d = durs_ref[e, :]
        act = d > 0
        asleep = act & (g >= tpdt)
        wake_add = jnp.where(asleep, tpdt + t_s + t_w + d, g + d)
        sleep_add = jnp.where(asleep, jnp.maximum(g - tpdt - t_s, 0.0), 0.0)
        af = asleep.astype(jnp.float32)
        return (wake + jnp.where(act, wake_add, 0.0),
                sleep + jnp.where(act, sleep_add, 0.0),
                nw + af, hit + (act & ~asleep).astype(jnp.float32), miss + af)

    z = jnp.zeros((gaps_ref.shape[1],), jnp.float32)
    wake, sleep, nw, hit, miss = lax.fori_loop(0, n_events, body,
                                               (z, z, z, z, z))
    tail = tail_ref[...]
    tail_sleeps = tail >= tpdt + t_s
    wake_ref[...] = wake + jnp.where(tail_sleeps, tpdt + t_s, tail)
    sleep_ref[...] = sleep + jnp.where(tail_sleeps, tail - tpdt - t_s, 0.0)
    nwake_ref[...] = nw
    hits_ref[...] = hit
    miss_ref[...] = miss


def port_energy_pallas(gaps, durs, tpdt, tail, *, t_w, t_s, interpret=False):
    """gaps/durs: (E, P) f32; tpdt/tail: (P,) f32.  Returns dict of (P,)."""
    E, P = gaps.shape
    assert E <= MAX_E, f"E={E} exceeds kernel cap; chunk at ops level"
    Pp = pl.cdiv(P, TILE_P) * TILE_P

    def padE(x):
        return jnp.zeros((E, Pp), jnp.float32).at[:, :P].set(
            x.astype(jnp.float32))

    def padP(x, fill=0.0):
        return jnp.full((Pp,), fill, jnp.float32).at[:P].set(
            x.astype(jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_kernel, t_w=float(t_w), t_s=float(t_s),
                          n_events=E),
        grid=(Pp // TILE_P,),
        in_specs=[pl.BlockSpec((E, TILE_P), lambda i: (0, i)),
                  pl.BlockSpec((E, TILE_P), lambda i: (0, i)),
                  pl.BlockSpec((TILE_P,), lambda i: (i,)),
                  pl.BlockSpec((TILE_P,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((TILE_P,), lambda i: (i,))] * 5,
        out_shape=[jax.ShapeDtypeStruct((Pp,), jnp.float32)] * 5,
        interpret=interpret,
    )(padE(gaps), padE(durs), padP(tpdt, fill=1e30), padP(tail))
    keys = ["time_wake", "time_sleep", "n_wake", "hits", "misses"]
    return {k: v[:P] for k, v in zip(keys, outs)}
