"""Pallas TPU kernel: PerfBound t_PDT bin selection over all ports at once.

Layout: ports tiled over the grid (TP rows/block), bins along lanes (B padded
to a lane multiple).  The reverse cumulative sum is computed as a matmul with
a lower-triangular ones matrix — MXU-friendly, no sequential scan — then the
leftmost feasible bin is selected with a one-hot reduction.

VMEM per block: counts/sums (TP x B f32) + the (B x B) triangular matrix:
128*256*4 * 2 + 256*256*4 = 518 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

TILE_P = 128
LANE = 128


def _kernel(counts_ref, sums_ref, n_ref, total_ref, centers_ref, tpdt_ref, *,
            n_bins, max_tpdt, tpdt_init):
    c = counts_ref[...]                       # (TP, Bp)
    s = sums_ref[...]
    N = n_ref[...]                            # (TP,)
    total = total_ref[...]
    centers = centers_ref[...]                # (Bp,)
    Bp = c.shape[-1]

    # reverse cumsum via triangular matmul: rcum[:, j] = sum_{i>=j} c[:, i]
    row = lax.broadcasted_iota(jnp.int32, (Bp, Bp), 0)
    col = lax.broadcasted_iota(jnp.int32, (Bp, Bp), 1)
    tri = (row >= col).astype(jnp.float32)
    rcum = jnp.dot(c, tri, preferred_element_type=jnp.float32)

    lane = lax.broadcasted_iota(jnp.int32, (1, Bp), 1)
    feas = (rcum <= N[:, None]) & (lane < n_bins)
    found = feas.any(axis=1)
    j = jnp.argmax(feas, axis=1)
    oh = (lane == j[:, None]).astype(jnp.float32)
    cj = (c * oh).sum(axis=1)
    sj = (s * oh).sum(axis=1)
    ctr = (centers[None, :] * oh).sum(axis=1)
    mean = jnp.where(cj > 0, sj / jnp.maximum(cj, 1e-30), ctr)
    t = jnp.where(found, mean, max_tpdt)
    tpdt_ref[...] = jnp.where(total > 0, t, tpdt_init)


def tpdt_select_pallas(counts, sums, N, total, centers, *, max_tpdt,
                       tpdt_init, interpret=False):
    P, B = counts.shape
    Pp = pl.cdiv(P, TILE_P) * TILE_P
    Bp = pl.cdiv(B, LANE) * LANE

    def pad(x, shape):
        return jnp.zeros(shape, x.dtype).at[tuple(slice(0, d)
                                                  for d in x.shape)].set(x)

    counts = pad(counts.astype(jnp.float32), (Pp, Bp))
    sums = pad(sums.astype(jnp.float32), (Pp, Bp))
    N = pad(N.astype(jnp.float32), (Pp,))
    total = pad(total.astype(jnp.float32), (Pp,))
    centers = pad(centers.astype(jnp.float32), (Bp,))

    out = pl.pallas_call(
        functools.partial(_kernel, n_bins=B, max_tpdt=float(max_tpdt),
                          tpdt_init=float(tpdt_init)),
        grid=(Pp // TILE_P,),
        in_specs=[
            pl.BlockSpec((TILE_P, Bp), lambda i: (i, 0)),
            pl.BlockSpec((TILE_P, Bp), lambda i: (i, 0)),
            pl.BlockSpec((TILE_P,), lambda i: (i,)),
            pl.BlockSpec((TILE_P,), lambda i: (i,)),
            pl.BlockSpec((Bp,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_P,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.float32),
        interpret=interpret,
    )(counts, sums, N, total, centers)
    return out[:P]
