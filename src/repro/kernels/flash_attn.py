"""Pallas TPU kernel: flash attention (forward) with GQA, causal and
sliding-window masking — the beyond-paper §Perf optimization for the
memory-bound train/prefill cells.

Why: the pure-JAX chunked attention materializes every (q-chunk x kv-chunk)
score tensor in HBM (the dry-run measures ~0.4 GB per chunk pair — the
dominant HBM-traffic term for train_4k/prefill_32k).  This kernel keeps
the score tile in VMEM: HBM traffic collapses to reading q/k/v once and
writing o once per layer.

Layout (per grid step, one (batch*kv-head, q-block) pair):
  q tile  (Bq, G*dh)   — G = query heads per kv head folded into lanes
  k/v     (Skv, dh)    — streamed over the kv grid axis, VMEM-resident
  scores  (G, Bq, Bkv) — VMEM scratch only, never HBM

Grid: (B*Hkv, nq, nk) with nk innermost (sequential accumulation; Pallas
TPU guarantees sequential grid order on the last axis).  Online softmax
state (m, l, acc) lives in VMEM scratch, carried across the nk axis.

VMEM per block (defaults Bq=512, Bkv=1024, dh=128, G<=8 at f32):
  q 0.25 MiB + k/v 1 MiB + acc 2 MiB + scores 4 MiB  ~= 7.5 MiB < 16 MiB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, block_q, block_kv, seq_q, seq_kv, G):
    """One (bh, iq, ik) grid step."""
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)          # (Bq, G*dh)
    k = k_ref[...].astype(jnp.float32)          # (Bkv, dh)
    v = v_ref[...].astype(jnp.float32)          # (Bkv, dh)
    Bq, Gdh = q.shape
    dh = Gdh // G
    qh = q.reshape(Bq, G, dh).transpose(1, 0, 2)            # (G, Bq, dh)

    s = jax.lax.dot_general(qh, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # (G, Bq, Bkv) + position masks
    q_pos = iq * block_q + lax.broadcasted_iota(jnp.int32,
                                                (1, Bq, 1), 1)
    k_pos = ik * block_kv + lax.broadcasted_iota(jnp.int32,
                                                 (1, 1, s.shape[-1]), 2)
    ok = (q_pos < seq_q) & (k_pos < seq_kv)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= q_pos - k_pos < window
    s = jnp.where(ok, s, NEG)

    m_prev = m_scr[...]                          # (G, Bq)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(ok, p, 0.0)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[..., None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        out = (acc_scr[...] / l[..., None]).transpose(1, 0, 2) \
            .reshape(Bq, G * dh)
        o_ref[...] = out.astype(o_ref.dtype)
        # logsumexp stats for the backward kernels: L = m + log(l)
        lse_ref[...] = m_scr[...] + jnp.log(l)


def _fold(q, k, v, B, Sq, Skv, Hkv, G, dh, Sqp, Skvp):
    """(B*Hkv, S, G*dh) layout: kv-head-major batch, heads in lanes."""
    qr = q.reshape(B, Sq, Hkv, G * dh).transpose(0, 2, 1, 3) \
          .reshape(B * Hkv, Sq, G * dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, dh)
    if Sqp != Sq:
        qr = jnp.pad(qr, ((0, 0), (0, Sqp - Sq), (0, 0)))
    if Skvp != Skv:
        kr = jnp.pad(kr, ((0, 0), (0, Skvp - Skv), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, Skvp - Skv), (0, 0)))
    return qr, kr, vr


def _geom(q, k, block_q, block_kv):
    B, Sq, H, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    assert H % Hkv == 0
    G = H // Hkv
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    nq = pl.cdiv(Sq, bq)
    nk = pl.cdiv(Skv, bkv)
    return B, Sq, Skv, H, Hkv, G, dh, bq, bkv, nq, nk, nq * bq, nk * bkv


def flash_attention_fwd_pallas(q, k, v, *, causal=True, window=None,
                               block_q=512, block_kv=1024, interpret=False):
    """Forward + logsumexp stats.  Returns (o (B,Sq,H,dh), lse (BH,G,Sqp))."""
    B, Sq, Skv, H, Hkv, G, dh, bq, bkv, nq, nk, Sqp, Skvp = _geom(
        q, k, block_q, block_kv)
    scale = 1.0 / math.sqrt(dh)
    qr, kr, vr = _fold(q, k, v, B, Sq, Skv, Hkv, G, dh, Sqp, Skvp)

    out, lse = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, block_q=bq, block_kv=bkv,
                          seq_q=Sq, seq_kv=Skv, G=G),
        grid=(B * Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, G * dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, bkv, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bkv, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, bq, G * dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, G, bq), lambda b, i, j: (b, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, Sqp, G * dh), q.dtype),
            jax.ShapeDtypeStruct((B * Hkv, G, Sqp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu_scratch((G, bq)),
            pltpu_scratch((G, bq)),
            pltpu_scratch((G, bq, dh)),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    o = out[:, :Sq].reshape(B, Hkv, Sq, G, dh).transpose(0, 2, 1, 3, 4)
    return o.reshape(B, Sq, H, dh), lse


def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           block_q=512, block_kv=1024, interpret=False):
    """q: (B, Sq, H, dh); k/v: (B, Skv, Hkv, dh).  Returns (B, Sq, H, dh).

    GQA folded: H = G * Hkv query heads share each kv head.  No dropout,
    no bias — matches repro.models.layers.attention_op semantics for the
    self-attention train/prefill path.
    """
    return flash_attention_fwd_pallas(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_kv=block_kv,
                                      interpret=interpret)[0]


# ---------------------------------------------------------------------------
# Backward kernels (FA2-style two-pass: dk/dv over kv blocks, dq over q)
# ---------------------------------------------------------------------------


def _masked_p(qh, k, lse, *, scale, causal, window, iq, ik, block_q,
              block_kv, seq_q, seq_kv):
    """Recompute p = exp(s - L) with position masks.  qh: (G,Bq,dh)."""
    s = jax.lax.dot_general(qh, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    Bq, Bkv = s.shape[1], s.shape[2]
    q_pos = iq * block_q + lax.broadcasted_iota(jnp.int32, (1, Bq, 1), 1)
    k_pos = ik * block_kv + lax.broadcasted_iota(jnp.int32, (1, 1, Bkv), 2)
    ok = (q_pos < seq_q) & (k_pos < seq_kv)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= q_pos - k_pos < window
    p = jnp.exp(jnp.where(ok, s, NEG) - lse[..., None])
    return jnp.where(ok, p, 0.0)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dcap_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr, *,
                    scale, causal, window, block_q, block_kv, seq_q,
                    seq_kv, G):
    """grid (BH, nk, nq) — q blocks innermost, accumulate dk/dv in VMEM."""
    ik = pl.program_id(1)
    iq = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[...].astype(jnp.float32)
    Bq, Gdh = q.shape
    dh = Gdh // G
    qh = q.reshape(Bq, G, dh).transpose(1, 0, 2)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    doh = do_ref[...].astype(jnp.float32).reshape(Bq, G, dh) \
        .transpose(1, 0, 2)
    lse = lse_ref[...]                        # (G, Bq)
    dcap = dcap_ref[...]                      # (G, Bq)  D = rowsum(do*o)

    p = _masked_p(qh, k, lse, scale=scale, causal=causal, window=window,
                  iq=iq, ik=ik, block_q=block_q, block_kv=block_kv,
                  seq_q=seq_q, seq_kv=seq_kv)          # (G,Bq,Bkv)
    # dv += sum_G p^T do
    dv_g = jax.lax.dot_general(p, doh, (((1,), (1,)), ((0,), (0,))))
    dv_scr[...] += dv_g.sum(axis=0)
    # ds = p * (do v^T - D) * scale;  dk += sum_G ds^T q
    dp = jax.lax.dot_general(doh, v, (((2,), (1,)), ((), ())))
    ds = p * (dp - dcap[..., None]) * scale
    dk_g = jax.lax.dot_general(ds, qh, (((1,), (1,)), ((0,), (0,))))
    dk_scr[...] += dk_g.sum(axis=0)

    @pl.when(iq == nq - 1)
    def _finish():
        dk_ref[...] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[...] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dcap_ref,
                   dq_ref, dq_scr, *, scale, causal, window, block_q,
                   block_kv, seq_q, seq_kv, G):
    """grid (BH, nq, nk) — kv blocks innermost, accumulate dq in VMEM."""
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[...].astype(jnp.float32)
    Bq, Gdh = q.shape
    dh = Gdh // G
    qh = q.reshape(Bq, G, dh).transpose(1, 0, 2)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    doh = do_ref[...].astype(jnp.float32).reshape(Bq, G, dh) \
        .transpose(1, 0, 2)
    lse = lse_ref[...]
    dcap = dcap_ref[...]

    p = _masked_p(qh, k, lse, scale=scale, causal=causal, window=window,
                  iq=iq, ik=ik, block_q=block_q, block_kv=block_kv,
                  seq_q=seq_q, seq_kv=seq_kv)
    dp = jax.lax.dot_general(doh, v, (((2,), (1,)), ((), ())))
    ds = p * (dp - dcap[..., None]) * scale
    dq_scr[...] += jax.lax.dot_general(ds, k, (((2,), (0,)), ((), ())))

    @pl.when(ik == nk - 1)
    def _finish():
        out = dq_scr[...].transpose(1, 0, 2).reshape(Bq, G * dh)
        dq_ref[...] = out.astype(dq_ref.dtype)


def flash_attention_bwd_pallas(q, k, v, o, lse, do, *, causal=True,
                               window=None, block_q=512, block_kv=1024,
                               interpret=False):
    """Returns (dq, dk, dv) with the input shapes/dtypes.  ``lse`` is the
    (BH, G, Sqp) stats tensor from flash_attention_fwd_pallas."""
    B, Sq, Skv, H, Hkv, G, dh, bq, bkv, nq, nk, Sqp, Skvp = _geom(
        q, k, block_q, block_kv)
    scale = 1.0 / math.sqrt(dh)
    qr, kr, vr = _fold(q, k, v, B, Sq, Skv, Hkv, G, dh, Sqp, Skvp)
    dor = _fold(do, k, v, B, Sq, Skv, Hkv, G, dh, Sqp, Skvp)[0]
    # D = rowsum(do * o) per (head, q position) — cheap, fused by XLA
    dcap = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)
    dcap = dcap.reshape(B, Sq, Hkv, G).transpose(0, 2, 3, 1) \
        .reshape(B * Hkv, G, Sq)
    if Sqp != Sq:
        dcap = jnp.pad(dcap, ((0, 0), (0, 0), (0, Sqp - Sq)))

    kw = dict(scale=scale, causal=causal, window=window, block_q=bq,
              block_kv=bkv, seq_q=Sq, seq_kv=Skv, G=G)
    common_in = [
        pl.BlockSpec((None, bq, G * dh), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((None, bkv, dh), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((None, bkv, dh), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((None, bq, G * dh), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((None, G, bq), lambda b, i, j: (b, 0, j)),
        pl.BlockSpec((None, G, bq), lambda b, i, j: (b, 0, j)),
    ]
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, **kw),
        grid=(B * Hkv, nk, nq),
        in_specs=common_in,
        out_specs=[pl.BlockSpec((None, bkv, dh), lambda b, i, j: (b, i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((B * Hkv, Skvp, dh), k.dtype)] * 2,
        scratch_shapes=[pltpu_scratch((bkv, dh)), pltpu_scratch((bkv, dh))],
        interpret=interpret,
    )(qr, kr, vr, dor, lse, dcap)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, **kw),
        grid=(B * Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, G * dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, bkv, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bkv, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bq, G * dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, G, bq), lambda b, i, j: (b, 0, i)),
            pl.BlockSpec((None, G, bq), lambda b, i, j: (b, 0, i)),
        ],
        out_specs=pl.BlockSpec((None, bq, G * dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, Sqp, G * dh), q.dtype),
        scratch_shapes=[pltpu_scratch((G, bq, dh))],
        interpret=interpret,
    )(qr, kr, vr, dor, lse, dcap)

    def unfold_q(x):
        x = x[:, :Sq].reshape(B, Hkv, Sq, G, dh).transpose(0, 2, 1, 3, 4)
        return x.reshape(B, Sq, H, dh)

    def unfold_kv(x):
        return x[:, :Skv].reshape(B, Hkv, Skv, dh).transpose(0, 2, 1, 3)

    return unfold_q(dq), unfold_kv(dk), unfold_kv(dv)


# ---------------------------------------------------------------------------
# Differentiable wrapper (custom_vjp)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=None, block_q=512,
                    block_kv=1024, interpret=False):
    return flash_attention_fwd_pallas(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, interpret=interpret)[0]


def _fa_fwd(q, k, v, causal, window, block_q, block_kv, interpret):
    o, lse = flash_attention_fwd_pallas(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, interpret=interpret)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, window, block_q, block_kv, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd_pallas(
        q, k, v, o, lse, do, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, interpret=interpret)
    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def pltpu_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
