"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced JAX ops, validating the exact pallas_call/BlockSpec
program against the ref.py oracles.  On TPU the same calls compile to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.hist_update import hist_update_pallas
from repro.kernels.port_energy import port_energy_pallas
from repro.kernels.tpdt_select import tpdt_select_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("max_tpdt", "tpdt_init", "use_ref"))
def tpdt_select_op(counts, sums, N, total, centers, *, max_tpdt, tpdt_init,
                   use_ref=False):
    f32 = lambda x: x.astype(jnp.float32)
    if use_ref:
        return ref.tpdt_select_ref(f32(counts), f32(sums), f32(N), f32(total),
                                   f32(centers), max_tpdt=max_tpdt,
                                   tpdt_init=tpdt_init)
    return tpdt_select_pallas(f32(counts), f32(sums), f32(N), f32(total),
                              f32(centers), max_tpdt=max_tpdt,
                              tpdt_init=tpdt_init, interpret=_interpret())


@partial(jax.jit, static_argnames=("n_bins", "bin_width", "log_bins",
                                   "log_min", "log_max", "use_ref"))
def hist_update_op(gaps, *, n_bins, bin_width, log_bins=False, log_min=1e-7,
                   log_max=10.0, use_ref=False):
    g = gaps.astype(jnp.float32)
    kw = dict(n_bins=n_bins, bin_width=bin_width, log_bins=log_bins,
              log_min=log_min, log_max=log_max)
    if use_ref:
        return ref.hist_update_ref(g, **kw)
    return hist_update_pallas(g, **kw, interpret=_interpret())


@partial(jax.jit, static_argnames=("t_w", "t_s", "t_w2", "t_s2", "use_ref"))
def port_energy_op(gaps, durs, tpdt, tail, t_dst=None, hold=None, *, t_w, t_s,
                   t_w2=0.0, t_s2=0.0, use_ref=False):
    """Per-port energy replay; the dual-mode row (t_w2/t_s2) engages for
    gaps past ``tpdt + max(t_dst, t_s)``.  The state-table rows are static
    (a 2-entry table), but ``t_dst`` — a continuously swept knob — is a
    TRACED scalar/(P,) operand, so a demotion-timer curve reuses one
    compiled kernel (None -> +inf -> single-state).  ``hold`` is the
    predictive hold-at-source row (precoalesce), equally traced
    (None -> 0 -> off): a hold_delay curve also reuses one kernel."""
    f32 = lambda x: x.astype(jnp.float32)
    if t_dst is None:
        t_dst = jnp.inf
    if hold is None:
        hold = 0.0
    t_dst = jnp.asarray(t_dst, jnp.float32)
    hold = jnp.asarray(hold, jnp.float32)
    kw = dict(t_w=t_w, t_s=t_s, t_w2=t_w2, t_s2=t_s2, t_dst=t_dst, hold=hold)
    if use_ref:
        return ref.port_energy_ref(f32(gaps), f32(durs), f32(tpdt), f32(tail),
                                   **kw)
    return port_energy_pallas(f32(gaps), f32(durs), f32(tpdt), f32(tail),
                              **kw, interpret=_interpret())


@partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                   "block_kv", "use_ref"))
def flash_attention_op(q, k, v, *, causal=True, window=None, block_q=512,
                       block_kv=1024, use_ref=False):
    """Differentiable flash attention (custom_vjp: Pallas fwd + FA2-style
    two-pass Pallas bwd)."""
    from repro.kernels.flash_attn import flash_attention
    if use_ref:
        return ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window)
    return flash_attention(q, k, v, causal, window, block_q, block_kv,
                           _interpret())


@partial(jax.jit, static_argnames=("chunk", "use_ref"))
def ssd_op(xs, dt, Bc, Cc, A, D, *, chunk=128, use_ref=False):
    """Mamba2 SSD chunked forward (fresh sequence)."""
    from repro.kernels.ssd import ssd_pallas
    if use_ref:
        return ref.ssd_ref(xs, dt, Bc, Cc, A, D, chunk=chunk)
    return ssd_pallas(xs, dt, Bc, Cc, A, D, chunk=chunk,
                      interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_op_vjp(xs, dt, Bc, Cc, A, D, *, chunk=128):
    """Differentiable SSD: Pallas forward + oracle-recompute backward."""
    from repro.kernels.ssd import ssd
    return ssd(xs, dt, Bc, Cc, A, D, chunk, _interpret())
