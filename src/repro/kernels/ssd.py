"""Pallas TPU kernel: Mamba2 SSD (state-space dual) chunked forward.

The pure-JAX chunk scan (repro.models.layers.mamba2_block) materializes
the (Q, Q) decay products and chunk summaries in HBM per chunk pair —
the dominant traffic for the hybrid arch (EXPERIMENTS.md §Perf).  This
kernel keeps everything per-chunk in VMEM: HBM traffic collapses to
reading the projected inputs once and writing y + the final state once
(the `ssm_impl=stub` contract, measured at 1.5–7.7× bound improvement).

Layout: grid `(B*H, nc)` — one (batch, head) stream per major grid row,
chunks sequential on the minor axis with the (N, P) SSM state carried in
VMEM scratch.  Per grid step (Q=128, N=64, P=64, f32):
  xs (Q,P) 32 KB + B/C (Q,N) 64 KB + M (Q,Q) 64 KB + state (N,P) 16 KB
  -> well under 1 MiB of VMEM.

Semantics (one head; a = exp(dt*A) log-decays):
  L_t   = cumsum_t(dt_t * A)                      (within chunk)
  y_t   = sum_{s<=t} C_t·B_s exp(L_t - L_s) dt_s x_s   (intra, causal)
        + C_t exp(L_t) h_in                            (inter)
  h_out = h_in exp(L_Q) + sum_s exp(L_Q - L_s) dt_s B_s x_s^T
  y    += D * x_t                                       (skip)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _kernel(xs_ref, dt_ref, b_ref, c_ref, a_ref, d_ref,
            y_ref, hout_ref, h_scr, *, n_chunks):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    xs = xs_ref[...].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[...].astype(jnp.float32)          # (1, Q)
    bc = b_ref[...].astype(jnp.float32)           # (Q, N)
    cc = c_ref[...].astype(jnp.float32)           # (Q, N)
    A = a_ref[0, 0]                               # scalar (this head)
    D = d_ref[0, 0]
    Q = xs.shape[0]

    dA = dt[0] * A                                # (Q,) log-decay, <= 0
    L = jnp.cumsum(dA)                            # (Q,)

    # intra-chunk causal mixing matrix M[t,s] = C_t·B_s e^{L_t-L_s} dt_s
    GB = jax.lax.dot_general(cc, bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    decay = jnp.exp(L[:, None] - L[None, :])
    row = lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.where(row >= col, GB * decay * dt[0][None, :], 0.0)
    y = jax.lax.dot_general(M, xs, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: carried state h (N, P)
    h = h_scr[...]
    y += jax.lax.dot_general(cc * jnp.exp(L)[:, None], h,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: h' = h e^{L_Q} + sum_s e^{L_Q - L_s} dt_s B_s xs_s^T
    w = jnp.exp(L[-1] - L) * dt[0]                # (Q,)
    upd = jax.lax.dot_general(bc * w[:, None], xs,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    h_scr[...] = h * jnp.exp(L[-1]) + upd

    y_ref[...] = (y + xs * D).astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _finish():
        hout_ref[...] = h_scr[...]


def ssd_pallas(xs, dt, Bc, Cc, A, D, *, chunk=128, h0=None,
               interpret=False):
    """Chunked SSD forward.

    xs: (B, S, H, P); dt: (B, S, H) post-softplus; Bc/Cc: (B, S, N)
    (shared across heads, Mamba2 convention); A: (H,) negative decays;
    D: (H,) skip gains.  Returns (y (B,S,H,P) f32, h (B,H,N,P) f32).
    ``h0`` (initial state) is not yet supported (train/prefill from
    scratch); decode uses the recurrent jax path.
    """
    assert h0 is None, "ssd_pallas: fresh-sequence only"
    B, S, H, P = xs.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    nc = pl.cdiv(S, Q)
    Sp = nc * Q
    if Sp != S:
        pad = ((0, 0), (0, Sp - S))
        xs = jnp.pad(xs, pad + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, pad + ((0, 0),))        # dt=0 -> no effect
        Bc = jnp.pad(Bc, pad + ((0, 0),))
        Cc = jnp.pad(Cc, pad + ((0, 0),))

    # (B*H, S, ...) streams; B/C broadcast over heads via index_map
    xsr = xs.transpose(0, 2, 1, 3).reshape(B * H, Sp, P)
    dtr = dt.transpose(0, 2, 1).reshape(B * H, 1, Sp)
    ar = jnp.broadcast_to(A.astype(jnp.float32)[None, :],
                          (B, H)).reshape(B * H, 1, 1)
    dr = jnp.broadcast_to(D.astype(jnp.float32)[None, :],
                          (B, H)).reshape(B * H, 1, 1)

    y, hT = pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((None, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, 1, Q), lambda b, c: (b, 0, c)),
            # B/C indexed by the BATCH of the (b, h) stream: b // H
            pl.BlockSpec((None, Q, N), lambda b, c, H=H: (b // H, c, 0)),
            pl.BlockSpec((None, Q, N), lambda b, c, H=H: (b // H, c, 0)),
            pl.BlockSpec((None, 1, 1), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((None, 1, 1), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, N, P), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sp, P), jnp.float32),
            jax.ShapeDtypeStruct((B * H, N, P), jnp.float32),
        ],
        scratch_shapes=[_vmem((N, P))],
        interpret=interpret,
    )(xsr, dtr, Bc, Cc, ar, dr)
    y = y[:, :S].reshape(B, H, S, P).transpose(0, 2, 1, 3)
    return y, hT.reshape(B, H, N, P)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward, reference-recompute backward
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def ssd(xs, dt, Bc, Cc, A, D, chunk=128, interpret=False):
    return ssd_pallas(xs, dt, Bc, Cc, A, D, chunk=chunk,
                      interpret=interpret)


def _ssd_fwd(xs, dt, Bc, Cc, A, D, chunk, interpret):
    out = ssd_pallas(xs, dt, Bc, Cc, A, D, chunk=chunk,
                     interpret=interpret)
    return out, (xs, dt, Bc, Cc, A, D)


def _ssd_bwd(chunk, interpret, res, cts):
    # backward = VJP of the pure-jnp oracle (flash-style recompute; the
    # dedicated bwd kernel is future work — the fwd kernel removes the
    # dominant traffic already, see EXPERIMENTS.md)
    from repro.kernels.ref import ssd_ref
    _, vjp = jax.vjp(lambda *a: ssd_ref(*a, chunk=chunk), *res)
    return vjp(cts)


ssd.defvjp(_ssd_fwd, _ssd_bwd)
