"""Pure-jnp oracles for every Pallas kernel (shape-for-shape identical)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tpdt_select_ref(counts, sums, N, total, centers, *, max_tpdt, tpdt_init):
    """PerfBound bin selection.  counts/sums: (P,B) f32; N/total: (P,).

    From the top bin downwards accumulate counts; choose the leftmost bin
    whose tail accumulation is <= N; t_PDT = mean of that bin (value sum /
    count, falling back to the bin center when empty).
    """
    rcum = jnp.cumsum(counts[:, ::-1], axis=1)[:, ::-1]
    feas = rcum <= N[:, None]
    found = feas.any(axis=1)
    j = jnp.argmax(feas, axis=1)
    oh = jax.nn.one_hot(j, counts.shape[1], dtype=counts.dtype)
    cj = (counts * oh).sum(1)
    sj = (sums * oh).sum(1)
    ctr = (centers[None, :] * oh).sum(1)
    mean = jnp.where(cj > 0, sj / jnp.maximum(cj, 1e-30), ctr)
    t = jnp.where(found, mean, max_tpdt)
    return jnp.where(total > 0, t, tpdt_init).astype(counts.dtype)


def hist_update_ref(gaps, *, n_bins, bin_width, log_bins=False,
                    log_min=1e-7, log_max=10.0):
    """Batched histogram build.  gaps: (E,P) f32 (<=0 entries ignored).
    Returns (counts (P,B), sums (P,B))."""
    E, P = gaps.shape
    valid = gaps > 0
    if log_bins:
        lo, hi = np.log(log_min), np.log(log_max)
        x = (jnp.log(jnp.maximum(gaps, log_min)) - lo) / (hi - lo)
        b = jnp.clip((x * n_bins).astype(jnp.int32), 0, n_bins - 1)
    else:
        b = jnp.clip((gaps / bin_width).astype(jnp.int32), 0, n_bins - 1)
    oh = (b[..., None] == jnp.arange(n_bins)[None, None, :]) & valid[..., None]
    counts = oh.sum(0).astype(jnp.float32)
    sums = (oh * jnp.where(valid, gaps, 0.0)[..., None]).sum(0)
    return counts, sums.astype(jnp.float32)


def port_energy_ref(gaps, durs, tpdt, tail, *, t_w, t_s,
                    t_w2=0.0, t_s2=0.0, t_dst=None, hold=None):
    """Decoupled per-port EEE/PDT replay (fixed per-port t_PDT) with the
    dual-mode sleep ladder: gaps past ``tpdt + max(t_dst, t_s)`` demote to
    the deep row (t_w2/t_s2); ``t_dst`` is a traced scalar or (P,) timer —
    None/inf is the single-state lowering.  ``hold`` is the predictive
    hold-at-source row: a frame that finds its port asleep defers by up to
    ``hold`` seconds, stretching the effective gap (None/0 = off).

    gaps/durs: (E,P) f32 — idle gap before each busy interval and its
    duration (duration 0 = padding).  tpdt/tail: (P,).
    Returns dict of (P,) arrays: time_wake, time_sleep, time_sleep2,
    n_wake, hits, misses, n_deep.
    """
    E, P = gaps.shape
    if t_dst is None:
        t_dst = jnp.inf
    if hold is None:
        hold = 0.0
    tds = jnp.maximum(jnp.asarray(t_dst, jnp.float32), jnp.float32(t_s))
    hld = jnp.asarray(hold, jnp.float32)

    def step(carry, ed):
        wake, sleep, sleep2, nw, hit, miss, nd = carry
        g, d = ed
        act = d > 0
        asleep = act & (g >= tpdt)
        ge = g + jnp.where(asleep, hld, 0.0)
        deep = act & (ge >= tpdt + tds)
        wake_add = jnp.where(
            asleep, jnp.where(deep, tpdt + t_s + t_s2 + t_w2 + d,
                              tpdt + t_s + t_w + d), g + d)
        sleep_add = jnp.where(
            asleep, jnp.where(deep, tds - t_s,
                              jnp.maximum(ge - tpdt - t_s, 0.0)), 0.0)
        sleep2_add = jnp.where(
            deep, jnp.maximum(ge - tpdt - tds - t_s2, 0.0), 0.0)
        return (wake + jnp.where(act, wake_add, 0.0),
                sleep + jnp.where(act, sleep_add, 0.0),
                sleep2 + sleep2_add,
                nw + asleep.astype(jnp.float32),
                hit + (act & ~asleep).astype(jnp.float32),
                miss + asleep.astype(jnp.float32),
                nd + deep.astype(jnp.float32)), None

    z = jnp.zeros((P,), jnp.float32)
    (wake, sleep, sleep2, nw, hit, miss, nd), _ = jax.lax.scan(
        step, (z, z, z, z, z, z, z), (gaps, durs))
    # close-out tail
    tail_sleeps = tail >= tpdt + t_s
    tail_deep = tail >= tpdt + tds + t_s2
    wake = wake + jnp.where(
        tail_sleeps, tpdt + t_s + jnp.where(tail_deep, t_s2, 0.0), tail)
    sleep = sleep + jnp.where(
        tail_sleeps, jnp.where(tail_deep, tds - t_s, tail - tpdt - t_s), 0.0)
    sleep2 = sleep2 + jnp.where(tail_deep, tail - tpdt - tds - t_s2, 0.0)
    return {"time_wake": wake, "time_sleep": sleep, "time_sleep2": sleep2,
            "n_wake": nw, "hits": hit, "misses": miss, "n_deep": nd}


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """Oracle for the flash-attention kernel: direct softmax attention with
    GQA head grouping, causal and sliding-window masks.  f32 math."""
    import math
    B, Sq, H, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    qg = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= q_pos - k_pos < window
    s = jnp.where(ok, s, -0.7 * jnp.finfo(jnp.float32).max)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh).astype(q.dtype)


def ssd_ref(xs, dt, Bc, Cc, A, D, *, chunk=128):
    """Oracle for the Mamba2 SSD kernel: direct (quadratic) evaluation.

    xs: (B,S,H,P) f32; dt: (B,S,H); Bc/Cc: (B,S,N); A/D: (H,).
    Returns (y (B,S,H,P) f32, h (B,H,N,P) f32)."""
    B, S, H, P = xs.shape
    xs32 = xs.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    dA = dt32 * A[None, None, :]                    # (B,S,H)
    L = jnp.cumsum(dA, axis=1)
    GB = jnp.einsum("btn,bsn->bts", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))         # (B,T,S)
    decay = jnp.exp(L[:, :, None, :] - L[:, None, :, :])   # (B,T,S,H)
    causal = jnp.tril(jnp.ones((S, S), bool))
    M = GB[..., None] * decay * dt32[:, None, :, :]
    M = jnp.where(causal[None, :, :, None], M, 0.0)
    y = jnp.einsum("btsh,bshp->bthp", M, xs32)
    y = y + xs32 * D[None, None, :, None]
    # final state
    w = jnp.exp(L[:, -1:, :] - L) * dt32            # (B,S,H)
    h = jnp.einsum("bsh,bsn,bshp->bhnp", w, Bc.astype(jnp.float32), xs32)
    return y, h
