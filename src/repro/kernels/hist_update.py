"""Pallas TPU kernel: batched inactivity-histogram build.

Ports along lanes (TILE_P=128), events along the sequential grid-free fori
axis; each step one-hot-accumulates a (TILE_P, B) update.  Inputs arrive
transposed (E, P) so the per-event row read is a natural (TILE_P,) vector.

VMEM per block: gaps (E x 128 f32) + two (128 x B) accumulators:
2048*128*4 + 2*128*256*4 = 1.3 MB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

TILE_P = 128
LANE = 128
MAX_E = 8192


def _kernel(gaps_ref, counts_ref, sums_ref, *, n_bins, bin_width, log_bins,
            log_min, log_max, n_events):
    E = gaps_ref.shape[0]
    Bp = counts_ref.shape[1]
    lane_b = lax.broadcasted_iota(jnp.int32, (1, Bp), 1)

    def body(e, carry):
        acc_c, acc_s = carry
        g = gaps_ref[e, :]                          # (TILE_P,)
        valid = g > 0
        if log_bins:
            lo, hi = math.log(log_min), math.log(log_max)
            x = (jnp.log(jnp.maximum(g, log_min)) - lo) / (hi - lo)
            b = jnp.clip((x * n_bins).astype(jnp.int32), 0, n_bins - 1)
        else:
            b = jnp.clip((g / bin_width).astype(jnp.int32), 0, n_bins - 1)
        oh = (lane_b == b[:, None]) & valid[:, None]
        ohf = oh.astype(jnp.float32)
        return acc_c + ohf, acc_s + ohf * jnp.where(valid, g, 0.0)[:, None]

    z = jnp.zeros((gaps_ref.shape[1], Bp), jnp.float32)
    acc_c, acc_s = lax.fori_loop(0, n_events, body, (z, z))
    counts_ref[...] = acc_c
    sums_ref[...] = acc_s


def hist_update_pallas(gaps, *, n_bins, bin_width, log_bins=False,
                       log_min=1e-7, log_max=10.0, interpret=False):
    """gaps: (E, P) f32.  Returns (counts (P,B), sums (P,B))."""
    E, P = gaps.shape
    assert E <= MAX_E, f"E={E} exceeds kernel cap; chunk at ops level"
    Pp = pl.cdiv(P, TILE_P) * TILE_P
    Bp = pl.cdiv(n_bins, LANE) * LANE
    g = jnp.zeros((E, Pp), jnp.float32).at[:, :P].set(gaps.astype(jnp.float32))

    counts, sums = pl.pallas_call(
        functools.partial(_kernel, n_bins=n_bins, bin_width=float(bin_width),
                          log_bins=bool(log_bins), log_min=float(log_min),
                          log_max=float(log_max), n_events=E),
        grid=(Pp // TILE_P,),
        in_specs=[pl.BlockSpec((E, TILE_P), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((TILE_P, Bp), lambda i: (i, 0)),
                   pl.BlockSpec((TILE_P, Bp), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Pp, Bp), jnp.float32),
                   jax.ShapeDtypeStruct((Pp, Bp), jnp.float32)],
        interpret=interpret,
    )(g)
    return counts[:P, :n_bins], sums[:P, :n_bins]
