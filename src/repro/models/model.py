"""Model assembly: init / forward / prefill / decode for every arch family.

Families
--------
* ``dense`` / ``moe`` / ``vlm``: decoder-only transformer (GQA, optional
  sliding-window:global mix, optional MoE MLPs, optional patch-embed prefix).
* ``encdec``: whisper-style encoder-decoder (learned positions, layernorm).
* ``hybrid``: Zamba2-style Mamba2 backbone + one shared attention block
  applied every ``attn_every`` layers.
* ``ssm``: RWKV6 (attention-free).

All stacks scan over layers with stacked params so the lowered HLO stays
small (one block body), which keeps 512-device dry-run compiles fast.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.distributed.ctx import constrain
from repro.models import layers as L


# ---------------------------------------------------------------------------
# Layer pattern helpers (static numpy, safe at trace time)
# ---------------------------------------------------------------------------


def layer_is_global(cfg) -> np.ndarray:
    """Per-layer flag: True => full (global) attention."""
    n = cfg.num_layers
    if cfg.sliding_window and cfg.global_layer_every:
        i = np.arange(n)
        return (i % cfg.global_layer_every) == (cfg.global_layer_every - 1)
    return np.ones(n, bool)


def hybrid_attn_sites(cfg):
    """(use_attn flags, site index per layer, n_sites) for hybrid archs."""
    i = np.arange(cfg.num_layers)
    use = (i % cfg.attn_every) == 0
    site = np.cumsum(use) - 1
    return use, np.maximum(site, 0), int(use.sum())


def _act_spec(cfg):
    return {"seq": ("B", "S", None), "batch": ("B", None, None),
            "dmodel": ("B", None, "M")}[cfg.act_shard]


def _maybe_remat(fn, cfg, mode):
    if not (cfg.remat and mode == "train") or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "save_coll":
        # keep the post-collective block outputs (tagged with
        # checkpoint_name below): the backward recompute stops at them,
        # so the forward TP all-reduces are not replayed (§Perf lever)
        policy = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "mlp_out", "moe_out", "mamba_out",
            "rwkv_tm_out", "rwkv_cm_out")
        return jax.checkpoint(fn, prevent_cse=False, policy=policy)
    return jax.checkpoint(fn, prevent_cse=False)


def _ckpt_name(x, name):
    from jax.ad_checkpoint import checkpoint_name
    return checkpoint_name(x, name)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _attn_block_params(key, cfg, cross=False):
    ks = jax.random.split(key, 5)
    p = {"ln1": L.norm_params(cfg.d_model, cfg.norm),
         "attn": L.attn_params(ks[0], cfg),
         "ln2": L.norm_params(cfg.d_model, cfg.norm)}
    if cfg.num_experts:
        p["moe"] = L.moe_params(ks[1], cfg)
    else:
        p["mlp"] = L.mlp_params(ks[1], cfg)
    if cross:
        p["ln_c"] = L.norm_params(cfg.d_model, cfg.norm)
        p["cross"] = L.attn_params(ks[2], cfg, cross=True)
    return p


def _mamba_block_params(key, cfg):
    return {"ln": L.norm_params(cfg.d_model, cfg.norm),
            "mamba": L.mamba2_params(key, cfg)}


def _rwkv_block_params(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.norm_params(cfg.d_model, cfg.norm),
            "tm": L.rwkv6_params(k1, cfg),
            "ln2": L.norm_params(cfg.d_model, cfg.norm),
            "cm": L.rwkv6_channelmix_params(k2, cfg)}


def init_params(cfg, key):
    keys = jax.random.split(key, 8)
    Vp, D = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": jax.random.normal(keys[0], (Vp, D), jnp.float32) * 0.02,
        "final_norm": L.norm_params(D, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[1], (D, Vp),
                                              jnp.float32) * 0.02

    lk = jax.random.split(keys[2], cfg.num_layers)
    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = jax.vmap(lambda k: _attn_block_params(k, cfg))(lk)
    elif cfg.family == "encdec":
        params["blocks"] = jax.vmap(
            lambda k: _attn_block_params(k, cfg, cross=True))(lk)
        ek = jax.random.split(keys[3], cfg.num_encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _attn_block_params(k, cfg))(ek),
            "final_norm": L.norm_params(D, cfg.norm),
        }
        params["pos_embed_dec"] = jax.random.normal(
            keys[4], (cfg.max_positions, D), jnp.float32) * 0.02
        params["pos_embed_enc"] = jax.random.normal(
            keys[5], (cfg.max_positions, D), jnp.float32) * 0.02
    elif cfg.family == "hybrid":
        params["blocks"] = jax.vmap(lambda k: _mamba_block_params(k, cfg))(lk)
        params["shared"] = _attn_block_params(keys[3], cfg)
    elif cfg.family == "ssm":
        params["blocks"] = jax.vmap(lambda k: _rwkv_block_params(k, cfg))(lk)
    else:
        raise ValueError(cfg.family)
    return params


def count_params(cfg, active_only=False) -> int:
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    if active_only and cfg.num_experts:
        per_expert = 3 * cfg.d_model * cfg.d_ff
        total -= cfg.num_layers * (cfg.num_experts -
                                   cfg.experts_per_token) * per_expert
    return total


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg):
    return params["embed"].astype(cfg.dtype)[tokens]


def logits_out(params, x, cfg):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(x.dtype).T
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return constrain(logits, "B", None, "M")


# ---------------------------------------------------------------------------
# Attention-family stacks (dense / moe / vlm / encdec-decoder)
# ---------------------------------------------------------------------------


def _attn_block_apply(x, bp, cfg, *, positions, window, causal,
                      cache=None, cache_len=None, cache_kind="linear",
                      cross_kv=None, cross_cached=None):
    """One transformer block.  Returns (x, aux, new_cache)."""
    h, new_cache = L.attention_block(
        L.norm(x, bp["ln1"], cfg.norm), bp["attn"], cfg,
        positions=positions, causal=causal, window=window,
        cache=cache, cache_len=cache_len, cache_kind=cache_kind)
    x = x + _ckpt_name(h, "attn_out")
    if cross_kv is not None or cross_cached is not None:
        h, _ = L.attention_block(
            L.norm(x, bp["ln_c"], cfg.norm), bp["cross"], cfg,
            positions=positions, causal=False, window=None,
            kv=cross_kv, precomputed_kv=cross_cached)
        x = x + _ckpt_name(h, "attn_out")
    aux = jnp.zeros((), jnp.float32)
    if cfg.num_experts:
        h, aux = L.moe_block(L.norm(x, bp["ln2"], cfg.norm), bp["moe"], cfg)
        h = _ckpt_name(h, "moe_out")
    else:
        h = _ckpt_name(L.mlp_block(L.norm(x, bp["ln2"], cfg.norm),
                                   bp["mlp"], cfg), "mlp_out")
    return x + h, aux, new_cache


def _stack_train(x, blocks, cfg, positions, *, causal=True, cross_kv=None):
    """Scan over layers, no cache.  Returns (x, aux_sum)."""
    flags = layer_is_global(cfg)
    mixed = cfg.sliding_window > 0 and not flags.all()
    win_arr = (jnp.where(jnp.asarray(flags), 2 ** 30, cfg.sliding_window)
               if mixed else None)

    def body(carry, xs):
        x, aux = carry
        if mixed:
            bp, win = xs
        else:
            bp, win = xs, (cfg.sliding_window or None)
        x, a, _ = _attn_block_apply(x, bp, cfg, positions=positions,
                                    window=win, causal=causal,
                                    cross_kv=cross_kv)
        x = constrain(x, *_act_spec(cfg))
        return (x, aux + a), None

    body = _maybe_remat(body, cfg, "train")
    xs = (blocks, win_arr) if mixed else blocks
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux


def _stack_with_cache(x, blocks, cfg, positions, cache, *, cross_len=0):
    """Scan over layers updating KV caches (prefill S>1 or decode S=1).

    Uniform archs: caches move through scan as xs->ys.
    Mixed local/global archs (gemma3): two cache stacks in carry with
    dynamic per-slot updates.
    Returns (x, aux, new_cache).
    """
    flags = layer_is_global(cfg)
    mixed = cfg.sliding_window > 0 and not flags.all()
    clen = cache["len"]
    encdec = cfg.is_encdec

    if not mixed:
        def body(carry, xs):
            x, aux = carry
            if encdec:
                bp, kc, vc, ck, cv = xs
                cross_cached = (ck, cv)
            else:
                bp, kc, vc = xs
                cross_cached = None
            x, a, nc = _attn_block_apply(
                x, bp, cfg, positions=positions,
                window=(cfg.sliding_window or None), causal=True,
                cache={"k": kc, "v": vc}, cache_len=clen,
                cache_kind="linear", cross_cached=cross_cached)
            x = constrain(x, *_act_spec(cfg))
            return (x, aux + a), (nc["k"], nc["v"])

        xs = ((blocks, cache["k"], cache["v"], cache["ck"], cache["cv"])
              if encdec else (blocks, cache["k"], cache["v"]))
        (x, aux), (nk, nv) = lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
        new_cache = dict(cache, k=nk, v=nv, len=clen + x.shape[1])
        return x, aux, new_cache

    # --- mixed sliding/global (gemma3) ---
    is_g = jnp.asarray(flags)
    slot_l = jnp.asarray(np.cumsum(~flags) - 1).clip(0)
    slot_g = jnp.asarray(np.cumsum(flags) - 1).clip(0)

    def body(carry, xs):
        x, aux, kl, vl, kg, vg = carry
        bp, gflag, sl, sg = xs

        def do_global(_):
            c = {"k": kg[sg], "v": vg[sg]}
            xo, a, nc = _attn_block_apply(x, bp, cfg, positions=positions,
                                          window=None, causal=True, cache=c,
                                          cache_len=clen, cache_kind="linear")
            return (xo, a, kl, vl,
                    kg.at[sg].set(nc["k"]), vg.at[sg].set(nc["v"]))

        def do_local(_):
            c = {"k": kl[sl], "v": vl[sl]}
            xo, a, nc = _attn_block_apply(x, bp, cfg, positions=positions,
                                          window=cfg.sliding_window,
                                          causal=True, cache=c, cache_len=clen,
                                          cache_kind="shift")
            return (xo, a, kl.at[sl].set(nc["k"]), vl.at[sl].set(nc["v"]),
                    kg, vg)

        xo, a, kl, vl, kg, vg = lax.cond(gflag, do_global, do_local, None)
        xo = constrain(xo, *_act_spec(cfg))
        return (xo, aux + a, kl, vl, kg, vg), None

    carry0 = (x, jnp.zeros((), jnp.float32),
              cache["k_local"], cache["v_local"],
              cache["k_global"], cache["v_global"])
    (x, aux, kl, vl, kg, vg), _ = lax.scan(
        body, carry0, (blocks, is_g, slot_l, slot_g))
    new_cache = dict(cache, k_local=kl, v_local=vl, k_global=kg, v_global=vg,
                     len=clen + x.shape[1])
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Hybrid (Zamba2) and SSM (RWKV6) stacks
# ---------------------------------------------------------------------------


def _hybrid_stack(x, params, cfg, positions, cache, mode):
    """Mamba2 backbone + shared attention block.  cache=None in train mode."""
    use, site, n_sites = hybrid_attn_sites(cfg)
    blocks, shared = params["blocks"], params["shared"]
    B, S, D = x.shape
    W = cfg.ssm_conv_width
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    decode = cache is not None
    if decode:
        clen, conv_s, ssm_s = cache["len"], cache["conv"], cache["ssm"]
        ka, va = cache["k"], cache["v"]
    else:
        clen = 0
        conv_s = jnp.zeros((cfg.num_layers, B, W - 1, conv_dim), cfg.dtype)
        ssm_s = jnp.zeros((cfg.num_layers, B, cfg.ssm_heads,
                           cfg.ssm_state, cfg.ssm_head_dim), jnp.float32)
        ka = va = None

    def body(carry, xs):
        x, ka, va = carry
        bp, uflag, st, cs, hs = xs

        def with_attn(x, ka, va):
            if decode:
                c = {"k": ka[st], "v": va[st]}
                h, nc = L.attention_block(
                    L.norm(x, shared["ln1"], cfg.norm), shared["attn"], cfg,
                    positions=positions, causal=True, window=None,
                    cache=c, cache_len=clen)
                ka, va = ka.at[st].set(nc["k"]), va.at[st].set(nc["v"])
            else:
                h, _ = L.attention_block(
                    L.norm(x, shared["ln1"], cfg.norm), shared["attn"], cfg,
                    positions=positions, causal=True, window=None)
            x = x + h
            x = x + L.mlp_block(L.norm(x, shared["ln2"], cfg.norm),
                                shared["mlp"], cfg)
            return x, ka, va

        def no_attn(x, ka, va):
            return x, ka, va

        if decode:
            x, ka, va = lax.cond(uflag, with_attn, no_attn, x, ka, va)
        else:
            x = lax.cond(uflag, lambda x: with_attn(x, None, None)[0],
                         lambda x: x, x)

        y, (ncs, nhs) = L.mamba2_block(
            L.norm(x, bp["ln"], cfg.norm), bp["mamba"], cfg,
            conv_state=cs, ssm_state=hs)
        y = _ckpt_name(y, "mamba_out")
        return (constrain(x + y, *_act_spec(cfg)), ka, va), (ncs, nhs)

    body = _maybe_remat(body, cfg, mode)
    xs = (blocks, jnp.asarray(use), jnp.asarray(site), conv_s, ssm_s)
    (x, ka, va), (ncs, nhs) = lax.scan(body, (x, ka, va), xs)
    new_cache = None
    if decode:
        new_cache = dict(cache, k=ka, v=va, conv=ncs, ssm=nhs,
                         len=clen + x.shape[1])
    elif mode == "prefill":
        new_cache = {"conv": ncs, "ssm": nhs, "len": x.shape[1]}
    return x, new_cache


def _ssm_stack(x, blocks, cfg, cache, mode):
    B, S, D = x.shape
    H, dh = cfg.rwkv_heads, cfg.rwkv_head_dim
    if cache is not None:
        wkv, sha, shf, clen = (cache["wkv"], cache["shift_a"],
                               cache["shift_f"], cache["len"])
    else:
        wkv = jnp.zeros((cfg.num_layers, B, H, dh, dh), jnp.float32)
        sha = jnp.zeros((cfg.num_layers, B, D), cfg.dtype)
        shf = jnp.zeros((cfg.num_layers, B, D), cfg.dtype)
        clen = 0

    use_state = cache is not None

    def body(carry, xs):
        x = carry
        bp, w0, sa0, sf0 = xs
        h, (w1, sa1) = L.rwkv6_timemix(
            L.norm(x, bp["ln1"], cfg.norm), bp["tm"], cfg,
            wkv_state=w0 if use_state else None,
            shift_state=sa0 if use_state else None)
        x = x + _ckpt_name(h, "rwkv_tm_out")
        h, sf1 = L.rwkv6_channelmix(
            L.norm(x, bp["ln2"], cfg.norm), bp["cm"],
            shift_state=sf0 if use_state else None)
        return constrain(x + _ckpt_name(h, "rwkv_cm_out"),
                         *_act_spec(cfg)), (w1, sa1, sf1)

    body = _maybe_remat(body, cfg, mode)
    x, (nw, nsa, nsf) = lax.scan(body, x, (blocks, wkv, sha, shf))
    new_cache = None
    if cache is not None or mode == "prefill":
        new_cache = {"wkv": nw, "shift_a": nsa.astype(cfg.dtype),
                     "shift_f": nsf.astype(cfg.dtype), "len": clen + S}
    return x, new_cache


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------


def encode(params, frames, cfg):
    """frames: (B, S_enc, D) stubbed post-conv features."""
    B, S, D = frames.shape
    x = frames.astype(cfg.dtype) + params["pos_embed_enc"][:S].astype(cfg.dtype)
    pos = jnp.arange(S)
    x, _ = _stack_train(x, params["encoder"]["blocks"], cfg, pos, causal=False)
    return L.norm(x, params["encoder"]["final_norm"], cfg.norm)


# ---------------------------------------------------------------------------
# Public API: forward / caches / decode
# ---------------------------------------------------------------------------


def forward(params, batch, cfg, mode="train"):
    """batch: {'tokens': (B,S)[, 'patch_embeds': (B,P,D)][, 'frames': (B,Se,D)]}.

    Returns {'logits', 'aux_loss'} and, when mode=='prefill', also 'cache'.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    if cfg.family == "vlm":
        P = cfg.num_patches
        pe = batch["patch_embeds"].astype(cfg.dtype)
        x = jnp.concatenate([pe, x[:, P:]], axis=1)
    positions = jnp.arange(S)
    aux = jnp.zeros((), jnp.float32)
    cache = None

    if cfg.family in ("dense", "moe", "vlm"):
        if mode == "prefill":
            cache = init_cache(cfg, B, S, dtype=cfg.dtype)
            x, aux, cache = _stack_with_cache(x, params["blocks"], cfg,
                                              positions, cache)
        else:
            x, aux = _stack_train(x, params["blocks"], cfg, positions)
    elif cfg.family == "encdec":
        x = x + params["pos_embed_dec"][:S].astype(cfg.dtype)
        enc = encode(params, batch["frames"], cfg)
        if mode == "prefill":
            cache = init_cache(cfg, B, S, enc_len=enc.shape[1], dtype=cfg.dtype)
            cache = fill_cross_cache(params, cache, enc, cfg)
            x, aux, cache = _stack_with_cache(x, params["blocks"], cfg,
                                              positions, cache)
        else:
            x, aux = _stack_train(x, params["blocks"], cfg, positions,
                                  cross_kv=enc)
    elif cfg.family == "hybrid":
        if mode == "prefill":
            cache = init_cache(cfg, B, S, dtype=cfg.dtype)
            x, cache = _hybrid_stack(x, params, cfg, positions, cache, mode)
        else:
            x, _ = _hybrid_stack(x, params, cfg, positions, None, mode)
    elif cfg.family == "ssm":
        x, cache = _ssm_stack(x, params["blocks"], cfg,
                              init_cache(cfg, B, S, dtype=cfg.dtype)
                              if mode == "prefill" else None, mode)
    else:
        raise ValueError(cfg.family)

    x = L.norm(x, params["final_norm"], cfg.norm)
    logits = logits_out(params, x, cfg)
    out = {"logits": logits, "aux_loss": aux}
    if mode == "prefill":
        out["cache"] = cache
    return out


def fill_cross_cache(params, cache, enc, cfg):
    """Precompute per-layer cross-attention K/V from encoder output."""
    H, dh = cfg.num_kv_heads, cfg.head_dim
    B, Se, D = enc.shape

    def per_layer(bp):
        k = (enc @ bp["cross"]["wk"].astype(enc.dtype))
        v = (enc @ bp["cross"]["wv"].astype(enc.dtype))
        if cfg.qkv_bias:
            k = k + bp["cross"]["bk"].astype(enc.dtype)
            v = v + bp["cross"]["bv"].astype(enc.dtype)
        return (k.reshape(B, Se, H, dh).astype(cache["ck"].dtype),
                v.reshape(B, Se, H, dh).astype(cache["cv"].dtype))

    ck, cv = jax.vmap(per_layer)(params["blocks"])
    return dict(cache, ck=ck, cv=cv)


def init_cache(cfg, batch, max_len, enc_len=1500, dtype=jnp.bfloat16):
    """Cache pytree sized for ``max_len`` total positions."""
    Lr, B = cfg.num_layers, batch
    Hkv, dh = cfg.num_kv_heads, cfg.head_dim
    zero = jnp.zeros
    if cfg.family in ("dense", "moe", "vlm"):
        flags = layer_is_global(cfg)
        mixed = cfg.sliding_window > 0 and not flags.all()
        if mixed:
            Ll, Lg = int((~flags).sum()), int(flags.sum())
            W = cfg.sliding_window
            return {"k_local": zero((Ll, B, W, Hkv, dh), dtype),
                    "v_local": zero((Ll, B, W, Hkv, dh), dtype),
                    "k_global": zero((Lg, B, max_len, Hkv, dh), dtype),
                    "v_global": zero((Lg, B, max_len, Hkv, dh), dtype),
                    "len": jnp.zeros((), jnp.int32)}
        return {"k": zero((Lr, B, max_len, Hkv, dh), dtype),
                "v": zero((Lr, B, max_len, Hkv, dh), dtype),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.family == "encdec":
        return {"k": zero((Lr, B, max_len, Hkv, dh), dtype),
                "v": zero((Lr, B, max_len, Hkv, dh), dtype),
                "ck": zero((Lr, B, enc_len, Hkv, dh), dtype),
                "cv": zero((Lr, B, enc_len, Hkv, dh), dtype),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        _, _, n_sites = hybrid_attn_sites(cfg)
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        return {"conv": zero((Lr, B, cfg.ssm_conv_width - 1, conv_dim), dtype),
                "ssm": zero((Lr, B, cfg.ssm_heads, cfg.ssm_state,
                             cfg.ssm_head_dim), jnp.float32),
                "k": zero((n_sites, B, max_len, Hkv, dh), dtype),
                "v": zero((n_sites, B, max_len, Hkv, dh), dtype),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        H, dh = cfg.rwkv_heads, cfg.rwkv_head_dim
        return {"wkv": zero((Lr, B, H, dh, dh), jnp.float32),
                "shift_a": zero((Lr, B, cfg.d_model), dtype),
                "shift_f": zero((Lr, B, cfg.d_model), dtype),
                "len": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.family)


def decode_step(params, cache, tokens, cfg):
    """One decode step.  tokens: (B,1).  Returns (logits (B,1,Vp), cache)."""
    B = tokens.shape[0]
    x = embed_tokens(params, tokens, cfg)
    clen = cache["len"]
    positions = jnp.broadcast_to(clen, (B, 1)).astype(jnp.int32)

    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        if cfg.family == "encdec":
            x = x + lax.dynamic_slice_in_dim(
                params["pos_embed_dec"], clen, 1).astype(cfg.dtype)
        x, aux, cache = _stack_with_cache(x, params["blocks"], cfg,
                                          positions, cache)
    elif cfg.family == "hybrid":
        x, cache = _hybrid_stack(x, params, cfg, positions, cache, "decode")
    elif cfg.family == "ssm":
        x, cache = _ssm_stack(x, params["blocks"], cfg, cache, "decode")
    else:
        raise ValueError(cfg.family)

    x = L.norm(x, params["final_norm"], cfg.norm)
    return logits_out(params, x, cfg), cache
