"""Model building blocks, pure-JAX, shard-friendly.

Conventions
-----------
* All activations are ``(B, S, ...)``; weights live in plain dict pytrees.
* Compute dtype is ``cfg.dtype`` (bf16 on TPU); softmax/normalization in f32.
* Attention uses a direct path for short sequences and a chunked
  (online-softmax, Rabe–Staats/flash-style) path for long ones, so the dry-run
  never materializes an ``S x S`` score matrix at 32k/500k.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.ctx import constrain

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm(x, p, kind):
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def norm_params(d, kind):
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim, theta):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta):
    """x: (B, S, H, dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

_NEG = -0.7 * jnp.finfo(jnp.float32).max


def _mask_bias(q_pos, k_pos, *, causal, window):
    """(…, Sq, Sk) additive f32 bias from position grids."""
    ok = jnp.ones(jnp.broadcast_shapes(q_pos[..., :, None].shape,
                                       k_pos[..., None, :].shape), bool)
    if causal:
        ok &= k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= q_pos[..., :, None] - k_pos[..., None, :] < window
    return jnp.where(ok, 0.0, _NEG).astype(jnp.float32)


def _direct_attention(q, k, v, q_pos, k_pos, *, causal, window, scale):
    """q: (B,Sq,H,dh), k/v: (B,Sk,Hkv,dh). GQA by head grouping."""
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window)[:, None, None]
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v, preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, dh).astype(q.dtype)


def _chunked_attention(q, k, v, q_pos, k_pos, *, causal, window, scale,
                       chunk_q, chunk_kv):
    """Flash-style attention: scan over KV chunks with online softmax, mapped
    over query chunks.  Memory is O(chunk_q * chunk_kv), never S^2."""
    B, Sq, H, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    cq = min(chunk_q, Sq)
    ckv = min(chunk_kv, Sk)
    # pad to multiples
    nq = -(-Sq // cq)
    nk = -(-Sk // ckv)
    pad_q, pad_k = nq * cq - Sq, nk * ckv - Sk
    q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # padded keys get position INT_MAX so causal mask kills them; also window
    k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=2**30)

    qc = q.reshape(B, nq, cq, H, dh).transpose(1, 0, 2, 3, 4)      # (nq,B,cq,H,dh)
    qp = q_pos.reshape(B, nq, cq).transpose(1, 0, 2)               # (nq,B,cq)
    kc = k.reshape(B, nk, ckv, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ckv, Hkv, dh).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(B, nk, ckv).transpose(1, 0, 2)

    @partial(jax.checkpoint, prevent_cse=False)
    def per_q_chunk(args):
        # rematerialized in backward: avoids retaining every (q,kv) tile's
        # softmax residuals across the whole sequence (flash-style memory)
        qi, qpi = args                                              # (B,cq,H,dh)
        qg = qi.reshape(B, cq, Hkv, G, dh)

        def kv_step(carry, kv):
            m, l, acc = carry
            kj, vj, kpj = kv
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj,
                           preferred_element_type=jnp.float32) * scale
            ok = jnp.ones((B, 1, 1, cq, ckv), bool)
            if causal:
                ok &= kpj[:, None, None, None, :] <= qpi[:, None, None, :, None]
            else:
                ok &= kpj[:, None, None, None, :] < 2**30
            if window is not None:
                ok &= (qpi[:, None, None, :, None] -
                       kpj[:, None, None, None, :]) < window
            s = jnp.where(ok, s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None]) * ok
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, cq, dh), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kc, vc, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(B, cq, H, dh).astype(q.dtype)

    out = lax.map(per_q_chunk, (qc, qp))                            # (nq,B,cq,H,dh)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * cq, H, dh)
    return out[:, :Sq]


def attention_op(q, k, v, q_pos, k_pos, *, causal, window, cfg):
    scale = 1.0 / math.sqrt(q.shape[-1])
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq > 1 and cfg.attn_impl != "jax":
        # fresh-sequence fast paths (train / from-scratch prefill only:
        # q_pos/k_pos are plain aranges there, which these paths assume)
        if cfg.attn_impl == "pallas":
            from repro.kernels.ops import flash_attention_op
            return flash_attention_op(q, k, v, causal=causal, window=window,
                                      block_q=cfg.attn_chunk_q,
                                      block_kv=cfg.attn_chunk_kv)
        if cfg.attn_impl == "stub":
            # the Pallas kernel's HBM contract: read q/k/v once, write o
            # once, nothing else materialized — used by the dry-run to
            # measure the kernel-backed memory roofline term
            G = q.shape[2] // k.shape[2]
            kv = (k.sum(1, keepdims=True) + v.sum(1, keepdims=True))
            return q + 1e-6 * jnp.repeat(kv, G, axis=2).astype(q.dtype)
    if max(Sq, Sk) <= cfg.attn_direct_max_seq or Sq == 1:
        return _direct_attention(q, k, v, q_pos, k_pos, causal=causal,
                                 window=window, scale=scale)
    return _chunked_attention(q, k, v, q_pos, k_pos, causal=causal,
                              window=window, scale=scale,
                              chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)


def attn_params(key, cfg, d_model=None, cross=False):
    d = d_model or cfg.d_model
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, H * dh), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, Hkv * dh), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, Hkv * dh), jnp.float32) * s,
        "wo": jax.random.normal(k4, (H * dh, d), jnp.float32) / math.sqrt(H * dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), jnp.float32)
        p["bk"] = jnp.zeros((Hkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((Hkv * dh,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


def attention_block(x, p, cfg, *, positions, causal, window, kv=None,
                    precomputed_kv=None, cache=None, cache_len=None,
                    cache_kind="linear"):
    """Self- or cross-attention.

    x: (B,S,D). kv: source for cross-attention (already normed encoder out).
    precomputed_kv: (k, v) already projected to (B,Sk,Hkv,dh) — cached
    cross-attention at decode time.
    cache: optional dict {'k','v'} with write pos ``cache_len`` (int32 scalar).
      * ``linear``: cache is (B, Smax, Hkv, dh), written at cache_len.
      * ``shift``: cache is (B, W, Hkv, dh) holding the last W tokens
        right-aligned (sliding-window layers; O(W) memory at any context).
    Returns (out, new_cache).
    """
    B, S, D = x.shape
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    is_cross = kv is not None or precomputed_kv is not None
    q = x @ p["wq"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, S, H, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])

    if precomputed_kv is not None:
        k, v = precomputed_kv
        k, v = k.astype(x.dtype), v.astype(x.dtype)
    else:
        src = kv if kv is not None else x
        k = src @ p["wk"].astype(x.dtype)
        v = src @ p["wv"].astype(x.dtype)
        if cfg.qkv_bias:
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
        k = k.reshape(B, src.shape[1], Hkv, dh)
        v = v.reshape(B, src.shape[1], Hkv, dh)
        if cfg.qk_norm:
            k = rmsnorm(k, p["k_norm"])
    if cfg.rope and not is_cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    q_pos = positions if positions.ndim == 2 else jnp.broadcast_to(
        positions[None], (B, S))

    new_cache = None
    if cache is not None and not is_cross:
        cdt = cache["k"].dtype
        if cache_kind == "linear":
            Smax = cache["k"].shape[1]
            # index dtypes must match even under x64 (tests enable it)
            z = jnp.zeros((), jnp.int32)
            at = (z, jnp.asarray(cache_len, jnp.int32), z, z)
            ck = lax.dynamic_update_slice(cache["k"], k.astype(cdt), at)
            cv = lax.dynamic_update_slice(cache["v"], v.astype(cdt), at)
            k_pos = jnp.broadcast_to(jnp.arange(Smax)[None], (B, Smax))
            # entries beyond the filled region masked via causal (pos 2**30)
            k_pos = jnp.where(k_pos < cache_len + S, k_pos, 2**30)
        else:  # shift (sliding window): keep last W tokens right-aligned
            W = cache["k"].shape[1]
            if S >= W:
                ck, cv = k[:, -W:].astype(cdt), v[:, -W:].astype(cdt)
            else:
                ck = jnp.concatenate([cache["k"][:, S:], k.astype(cdt)], axis=1)
                cv = jnp.concatenate([cache["v"][:, S:], v.astype(cdt)], axis=1)
            end = cache_len + S  # total tokens seen after this call
            if S > 1:
                # prefill: early queries need keys older than the retained
                # window, so attend over the full fresh sequence (requires a
                # fresh cache, cache_len == 0) and store only the last W.
                new_cache = {"k": ck, "v": cv}
                k_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
                o = attention_op(q, k, v, q_pos, k_pos, causal=causal,
                                 window=window, cfg=cfg)
                out = o.reshape(B, S, H * dh) @ p["wo"].astype(x.dtype)
                return out, new_cache
            k_pos = end - W + jnp.arange(W)[None]
            k_pos = jnp.where(k_pos >= 0, k_pos, 2**30)
            k_pos = jnp.broadcast_to(k_pos, (B, W))
        new_cache = {"k": ck, "v": cv}
        k, v = ck.astype(x.dtype), cv.astype(x.dtype)
    else:
        Sk = k.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))

    o = attention_op(q, k, v, q_pos, k_pos,
                     causal=causal and not is_cross, window=window, cfg=cfg)
    out = o.reshape(B, S, H * dh) @ p["wo"].astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_params(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    f = cfg.d_ff
    ks = jax.random.split(key, 3)
    s1, s2 = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {"w1": jax.random.normal(ks[0], (d, f), jnp.float32) * s1,
         "w2": jax.random.normal(ks[1], (f, d), jnp.float32) * s2}
    if cfg.act == "swiglu":
        p["w3"] = jax.random.normal(ks[2], (d, f), jnp.float32) * s1
    return p


def mlp_block(x, p, cfg):
    h = x @ p["w1"].astype(x.dtype)
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"].astype(x.dtype))
    elif cfg.act == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return h @ p["w2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based scatter dispatch, EP-shardable)
# ---------------------------------------------------------------------------


def moe_params(key, cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    s1, s2 = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * s1,
        "we1": jax.random.normal(ks[1], (E, d, f), jnp.float32) * s1,
        "we3": jax.random.normal(ks[2], (E, d, f), jnp.float32) * s1,
        "we2": jax.random.normal(ks[3], (E, f, d), jnp.float32) * s2,
    }


def moe_block(x, p, cfg):
    """Top-k routed MoE with fixed expert capacity.

    ``moe_dispatch='global'``: tokens scatter into ONE ``(E, C, D)`` buffer
    sharded over experts only — simple, but every device computes the FULL
    global capacity (DP-redundant expert GEMMs).

    ``moe_dispatch='dp'``: two-level (hierarchical) dispatch — tokens are
    grouped by their data-parallel shard, positions/capacity are computed
    PER GROUP (no cross-shard cumsum), and the buffer is ``(Gdp, E, Cl, D)``
    sharded (data, model): expert GEMM FLOPs scale with DP and only the
    per-group expert gather crosses the model axis (the all-to-all).
    Returns (out, aux_loss).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = lax.top_k(probs, K)                       # (T,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32),
                       axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_prob)

    # token groups: the DP degree when dispatch is hierarchical, else 1
    Gdp = 1
    if cfg.moe_dispatch == "dp":
        from repro.distributed.ctx import _axis_size, _mesh, batch_axes
        mesh = _mesh()
        ax = batch_axes(mesh) if mesh else None
        if ax is not None:
            g = _axis_size(mesh, ax)
            if B % g == 0:
                Gdp = g
    Tl = T // Gdp
    C = max(1, int(math.ceil(Tl * K / E * cfg.moe_capacity_factor)))

    idx_g = idx.reshape(Gdp, Tl, K)
    onehot = jax.nn.one_hot(idx_g, E, dtype=jnp.int32)    # (G,Tl,K,E)
    # position of each (token, k) within its group-local expert queue
    pos_all = jnp.cumsum(onehot.reshape(Gdp, Tl * K, E), axis=1) - 1
    pos = jnp.take_along_axis(pos_all.reshape(Gdp, Tl, K, E),
                              idx_g[..., None], axis=-1)[..., 0]  # (G,Tl,K)
    keep = pos < C
    pos_c = jnp.minimum(pos, C - 1)

    xg = xt.reshape(Gdp, Tl, D)
    upd = jnp.where(keep[..., None], xg[:, :, None, :], 0) \
        .reshape(Gdp, Tl * K, D)
    e_ix = idx_g.reshape(Gdp, Tl * K)
    s_ix = pos_c.reshape(Gdp, Tl * K)

    # vmapped per-group scatter: G becomes a scatter BATCH dim, so GSPMD
    # keeps the scatter local to each data shard (no cross-shard cumsum,
    # no replication)
    def scatter_group(u, e, s):
        return jnp.zeros((E, C, D), x.dtype).at[e, s].add(u, mode="drop")

    buf = jax.vmap(scatter_group)(upd, e_ix, s_ix)        # (G,E,C,D)
    buf = constrain(buf, "B", None, None, None)           # dispatch local
    # experts to model shards — THE MoE all-to-all (G stays on data)
    buf = constrain(buf, "B", "M", None, None)

    h = jnp.einsum("gecd,edf->gecf", buf, p["we1"].astype(x.dtype))
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", buf,
                                    p["we3"].astype(x.dtype))
    y = jnp.einsum("gecf,efd->gecd", h, p["we2"].astype(x.dtype))
    y = constrain(y, "B", "M", None, None)
    y = constrain(y, "B", None, None, None)               # return a2a

    out_k = jax.vmap(lambda yy, e, s: yy[e, s])(y, e_ix, s_ix)
    out_k = out_k.reshape(Gdp, Tl, K, D)
    out_k = jnp.where(keep[..., None], out_k, 0)
    out = jnp.sum(out_k * gate.reshape(Gdp, Tl, K)[..., None]
                  .astype(x.dtype), axis=2)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD, chunked)
# ---------------------------------------------------------------------------


def mamba2_params(key, cfg):
    d, di, N, H, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_conv_width)
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    conv_dim = di + 2 * N
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * N + H),
                                     jnp.float32) * s,
        "conv_w": jax.random.normal(ks[1], (W, conv_dim), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2, jnp.float32))),
        "ssm_norm": jnp.zeros((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[3], (di, d), jnp.float32)
        / math.sqrt(di),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B,S,C); w: (W,C) depthwise.  state: (B,W-1,C) carried for decode."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
        new_state = xp[:, -(W - 1):] if W > 1 else None
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(W - 1):] if W > 1 else None
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    return out + b.astype(x.dtype), new_state


def mamba2_block(x, p, cfg, *, conv_state=None, ssm_state=None):
    """Chunked SSD forward.  Returns (y, (conv_state, ssm_state))."""
    B, S, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,S,H)
    A = -jnp.exp(p["A_log"])                                       # (H,)
    dA = dt * A                                                    # log-decay
    Bc32, Cc32, xs32 = (Bc.astype(jnp.float32), Cc.astype(jnp.float32),
                        xs.astype(jnp.float32))

    if cfg.ssm_impl == "stub":
        # the SSD kernel's HBM contract: read xs/B/C/dt once, write y and
        # the final state once — chunk decay tensors stay in VMEM
        extra = (Bc32.sum(-1) + Cc32.sum(-1))[..., None, None] \
            + dt[..., None]
        y = xs32 * p["D"][None, None, :, None] + 1e-6 * extra
        y = y.reshape(B, S, di).astype(x.dtype)
        y = y * jax.nn.silu(z)
        y = rmsnorm(y, p["ssm_norm"])
        hT = jnp.zeros((B, H, N, P), jnp.float32) + 1e-6 * dA.sum()
        return y @ p["out_proj"].astype(x.dtype), (new_conv, hT)

    if cfg.ssm_impl == "pallas" and ssm_state is None and S > 1:
        # VMEM-tiled SSD kernel (fresh sequence; decode stays recurrent)
        from repro.kernels.ops import ssd_op_vjp
        y32, hT = ssd_op_vjp(xs32, dt, Bc32, Cc32, A, p["D"],
                             chunk=cfg.ssm_chunk)
        y = y32.reshape(B, S, di).astype(x.dtype)
        y = y * jax.nn.silu(z)
        y = rmsnorm(y, p["ssm_norm"])
        return y @ p["out_proj"].astype(x.dtype), (new_conv, hT)

    Q = min(cfg.ssm_chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bp = jnp.pad(Bc32, ((0, 0), (0, pad), (0, 0)))
        Cp = jnp.pad(Cc32, ((0, 0), (0, pad), (0, 0)))
        xp = jnp.pad(xs32, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        dtp, Bp, Cp, xp = dt, Bc32, Cc32, xs32
    dA = dA.reshape(B, nc, Q, H)
    dtc = dtp.reshape(B, nc, Q, H)
    Bch = Bp.reshape(B, nc, Q, N)
    Cch = Cp.reshape(B, nc, Q, N)
    xch = xp.reshape(B, nc, Q, H, P)

    L = jnp.cumsum(dA, axis=2)                                     # (B,nc,Q,H)
    # intra-chunk: M[t,s] = C_t·B_s * exp(L_t - L_s) * dt_s  (causal incl diag)
    GB = jnp.einsum("bcqn,bcsn->bcqs", Cch, Bch)
    decay = jnp.exp(L[:, :, :, None, :] - L[:, :, None, :, :])     # (B,nc,Q,Q,H)
    causal_m = jnp.tril(jnp.ones((Q, Q), bool))
    M = GB[..., None] * decay * dtc[:, :, None, :, :]
    M = jnp.where(causal_m[None, None, :, :, None], M, 0.0)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", M, xch)

    # chunk summaries: contribution of chunk to state at its end
    end_decay = jnp.exp(L[:, :, -1:, :] - L)                       # (B,nc,Q,H)
    Sc = jnp.einsum("bcqh,bcqn,bcqhp->bchnp",
                    end_decay * dtc, Bch, xch)                      # (B,nc,H,N,P)

    Ldec = jnp.exp(L)                                              # (B,nc,Q,H)

    def chunk_step(h, inp):
        Sc_c, Ldec_c, C_c = inp
        y_int = jnp.einsum("bqn,bqh,bhnp->bqhp", C_c, Ldec_c, h)
        h_new = h * Ldec_c[:, -1][:, :, None, None] + Sc_c
        return h_new, y_int

    h0 = (ssm_state.astype(jnp.float32) if ssm_state is not None
          else jnp.zeros((B, H, N, P), jnp.float32))
    hT, y_inter = lax.scan(
        chunk_step, h0,
        (Sc.transpose(1, 0, 2, 3, 4), Ldec.transpose(1, 0, 2, 3),
         Cch.transpose(1, 0, 2, 3)))
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)                     # (B,nc,Q,H,P)

    y = (y_intra + y_inter).reshape(B, nc * Q, H, P)[:, :S]
    y = y + xs32 * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["ssm_norm"])
    out = y @ p["out_proj"].astype(x.dtype)
    return out, (new_conv, hT.astype(jnp.float32))


def mamba2_decode_step(x, p, cfg, conv_state, ssm_state):
    """Single-token recurrent update.  x: (B,1,D)."""
    return mamba2_block(x, p, cfg, conv_state=conv_state, ssm_state=ssm_state)


# ---------------------------------------------------------------------------
# RWKV6 (Finch): data-dependent decay linear attention, chunked
# ---------------------------------------------------------------------------


def rwkv6_params(key, cfg):
    d, dh, H = cfg.d_model, cfg.rwkv_head_dim, cfg.rwkv_heads
    r = 64  # decay LoRA rank
    ks = jax.random.split(key, 10)
    s = 1.0 / math.sqrt(d)
    return {
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "w_r": jax.random.normal(ks[0], (d, d), jnp.float32) * s,
        "w_k": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "w_v": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
        "w_g": jax.random.normal(ks[3], (d, d), jnp.float32) * s,
        "w_o": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
        "w0": jnp.full((d,), -6.0, jnp.float32),     # decay bias (log-log space)
        "wa": jax.random.normal(ks[5], (d, r), jnp.float32) * s,
        "wb": jax.random.normal(ks[6], (r, d), jnp.float32) / math.sqrt(r),
        "u": jnp.zeros((d,), jnp.float32),           # per-channel bonus
        "ln_x": jnp.zeros((dh,), jnp.float32),       # per-head groupnorm scale
    }


def _token_shift(x, shift_state):
    """Returns (prev_token_seq, new_shift_state). x: (B,S,D)."""
    if shift_state is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([shift_state[:, None].astype(x.dtype),
                                x[:, :-1]], axis=1)
    return prev, x[:, -1]


def rwkv6_timemix(x, p, cfg, *, wkv_state=None, shift_state=None):
    """Chunked WKV.  Returns (out, (wkv_state, shift_state))."""
    B, S, D = x.shape
    H, dh = cfg.rwkv_heads, cfg.rwkv_head_dim
    prev, new_shift = _token_shift(x, shift_state)

    def lerp(mu):
        return x + (prev - x) * mu.astype(x.dtype)

    r = (lerp(p["mu_r"]) @ p["w_r"].astype(x.dtype)).reshape(B, S, H, dh)
    k = (lerp(p["mu_k"]) @ p["w_k"].astype(x.dtype)).reshape(B, S, H, dh)
    v = (lerp(p["mu_v"]) @ p["w_v"].astype(x.dtype)).reshape(B, S, H, dh)
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["w_g"].astype(x.dtype))
    xw = lerp(p["mu_w"]).astype(jnp.float32)
    lw = -jnp.exp(p["w0"] + jnp.tanh(xw @ p["wa"]) @ p["wb"])      # log decay <0
    lw = lw.reshape(B, S, H, dh)
    u = p["u"].reshape(H, dh)

    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))

    if cfg.ssm_impl == "stub":
        # the WKV kernel's HBM contract: read r/k/v/decay once, write y +
        # final state once — chunk score tiles stay in VMEM
        y = v32 + 1e-6 * (r32 + k32 + lw)
        y = rmsnorm(y.reshape(B, S, H, dh), p["ln_x"])
        y = y.reshape(B, S, D).astype(x.dtype) * g
        ST = jnp.zeros((B, H, dh, dh), jnp.float32) + 1e-6 * u.sum()
        return y @ p["w_o"].astype(x.dtype), (ST, new_shift)

    Q = min(cfg.ssm_chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        r32 = jnp.pad(r32, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k32 = jnp.pad(k32, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v32 = jnp.pad(v32, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    rc = r32.reshape(B, nc, Q, H, dh)
    kc = k32.reshape(B, nc, Q, H, dh)
    vc = v32.reshape(B, nc, Q, H, dh)
    lwc = lw.reshape(B, nc, Q, H, dh)
    cum = jnp.cumsum(lwc, axis=2)                                  # (B,nc,Q,H,dh)

    # intra-chunk: out_t += sum_{s<t} ((r_t*exp(cum_t - cum_s)) . k_s) v_s
    #              + ((r_t*u) . k_t) v_t
    ri = rc * jnp.exp(cum)
    ki = kc * jnp.exp(-cum)
    att = jnp.einsum("bcqhd,bcshd->bchqs", ri, ki)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    att = jnp.where(mask[None, None, None], att, 0.0)
    diag = jnp.einsum("bcqhd,hd,bcqhd->bchq", rc, u, kc)
    y = jnp.einsum("bchqs,bcshd->bcqhd", att, vc)
    y = y + diag[..., None].transpose(0, 1, 3, 2, 4) * vc

    # inter-chunk
    end = cum[:, :, -1:]                                           # (B,nc,1,H,dh)
    k_end = kc * jnp.exp(end - cum)                                # decay to end
    Sc = jnp.einsum("bcqhd,bcqhe->bchde", k_end, vc)               # (B,nc,H,dh,dh)

    def chunk_step(Sstate, inp):
        Sc_c, ri_c, end_c = inp
        y_int = jnp.einsum("bqhd,bhde->bqhe", ri_c, Sstate)
        S_new = Sstate * jnp.exp(end_c[:, 0])[..., None] + Sc_c
        return S_new, y_int

    S0 = (wkv_state.astype(jnp.float32) if wkv_state is not None
          else jnp.zeros((B, H, dh, dh), jnp.float32))
    ST, y_inter = lax.scan(
        chunk_step, S0,
        (Sc.transpose(1, 0, 2, 3, 4), ri.transpose(1, 0, 2, 3, 4),
         end.transpose(1, 0, 2, 3, 4)))
    y = y + y_inter.transpose(1, 0, 2, 3, 4)
    y = y.reshape(B, nc * Q, H, dh)[:, :S]

    y = rmsnorm(y, p["ln_x"])                                      # per-head norm
    y = y.reshape(B, S, D).astype(x.dtype) * g
    out = y @ p["w_o"].astype(x.dtype)
    return out, (ST.astype(jnp.float32), new_shift)


def rwkv6_channelmix_params(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "w_k": jax.random.normal(ks[0], (d, f), jnp.float32) / math.sqrt(d),
        "w_v": jax.random.normal(ks[1], (f, d), jnp.float32) / math.sqrt(f),
        "w_r": jax.random.normal(ks[2], (d, d), jnp.float32) / math.sqrt(d),
    }


def rwkv6_channelmix(x, p, *, shift_state=None):
    prev, new_shift = _token_shift(x, shift_state)
    xk = x + (prev - x) * p["mu_k"].astype(x.dtype)
    xr = x + (prev - x) * p["mu_r"].astype(x.dtype)
    h = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["w_r"].astype(x.dtype)) * (h @ p["w_v"].astype(x.dtype))
    return out, new_shift
