import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count on first init).  512 placeholder host devices back both production
meshes: (data=16, model=16) single-pod and (pod=2, data=16, model=16)
multi-pod.

Per cell we record ``compiled.memory_analysis()`` (proves the cell fits),
``compiled.cost_analysis()`` (FLOPs/bytes for the roofline), and the
collective-op byte census parsed from the compiled HLO (for the collective
roofline term).  Results land in experiments/dryrun/<cell>.json and are
resumable — existing JSONs are skipped unless --force.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from pathlib import Path  # noqa: E402

import jax             # noqa: E402

from repro.analysis.hlo import collective_census, module_cost  # noqa: E402
from repro.configs.base import (SHAPES, cell_is_runnable,  # noqa: E402
                                get_config, list_archs)
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.specs import build_cell                  # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def cell_name(arch, shape, multi_pod):
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force=False, verbose=True, overrides=None, tag=""):
    """``overrides``: ModelConfig fields to replace (perf experiments);
    ``tag`` suffixes the JSON name so variants never clobber baselines."""
    import dataclasses
    out_dir.mkdir(parents=True, exist_ok=True)
    name = cell_name(arch, shape_name, multi_pod) + (f"__{tag}" if tag else "")
    path = out_dir / (name + ".json")
    if path.exists() and not force:
        return json.loads(path.read_text())
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": 512 if multi_pod else 256,
           "overrides": overrides or {}, "tag": tag}
    ok, reason = cell_is_runnable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        path.write_text(json.dumps(rec, indent=1))
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            if verbose:
                print(mem)
            mem_rec = {}
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    mem_rec[k] = int(v)

            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            if verbose:
                print({k: v for k, v in sorted(cost.items())
                       if k in ("flops", "bytes accessed")})
            cost_rec = {k: float(v) for k, v in cost.items()
                        if isinstance(v, (int, float))}

            hlo_text = compiled.as_text()
            census = collective_census(hlo_text)
            # trip-count-corrected FLOPs/HBM bytes (cost_analysis counts
            # while bodies once — useless for scanned-layer models)
            hcost = module_cost(hlo_text)
    except Exception as e:  # record the failure — failures are bugs
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        path.write_text(json.dumps(rec, indent=1))
        raise
    rec.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem_rec,
        cost=cost_rec,
        hlo_cost={"flops": hcost["flops"], "bytes": hcost["bytes"]},
        collectives=census,
        param_count=cfg.param_count(),
        active_param_count=cfg.active_param_count(),
        tokens=shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1),
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        kind=shape.kind,
    )
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--tag", default="", help="variant suffix for the JSON")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="ModelConfig override, e.g. --set attn_impl=stub "
                         "--set remat=False (perf experiments)")
    args = ap.parse_args()
    out = Path(args.out)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        overrides[k] = v

    meshes = [False, True]
    if args.multi_pod and not args.single_pod:
        meshes = [True]
    if args.single_pod and not args.multi_pod:
        meshes = [False]

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    if not (args.all or args.arch or args.shape):
        ap.error("pass --all or --arch/--shape")

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = cell_name(arch, shape, mp)
                try:
                    rec = run_cell(arch, shape, mp, out, force=args.force,
                                   overrides=overrides or None,
                                   tag=args.tag)
                    status = rec["status"]
                    extra = ""
                    if status == "ok":
                        tb = rec["memory"].get("temp_size_in_bytes", 0)
                        extra = (f" compile={rec['compile_s']:.0f}s "
                                 f"temp/dev={tb/2**30:.2f}GiB "
                                 f"flops={rec['cost'].get('flops', 0):.3g}")
                    print(f"[{status:7s}] {name}{extra}", flush=True)
                except Exception as e:
                    failures.append(name)
                    print(f"[FAILED ] {name}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures: {failures}")
        raise SystemExit(1)
    print("\nall cells passed")


if __name__ == "__main__":
    main()
