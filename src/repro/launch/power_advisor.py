"""Power advisor: evaluate EEE link power-management policies for a
compiled LLM training/serving job BEFORE running it on hardware.

This is the framework's first-class integration of the paper's technique
with the LLM substrate (DESIGN.md §2 Layer B): the multi-pod dry-run's
compiled HLO gives the collective schedule (bytes, op mix, per-layer loop
structure); this module maps that schedule onto the paper's 4160-node
Megafly as a phase-structured trace and replays it under any Policy with
the coupled simulator.

Traffic attribution (architecture-true for this framework's sharding):
  * all-gather / reduce-scatter / all-to-all / collective-permute traffic
    comes from the model axis (TP/EP/SP) — emitted per layer inside each
    16-node TP group (which sits inside one Megafly group: TP rides the
    cheap local links, as the paper's own LLM motivation suggests);
  * all-reduce traffic is the data-parallel gradient reduction — emitted
    once per step across TP-rank-aligned nodes in different groups.

Compute time per step = HLO_FLOPs / (devices x peak x MFU), so the trace's
compute:communicate duty cycle matches the compiled job.

Two front doors: ``advise`` evaluates a fixed policy grid for a compiled
dry-run cell (above), and ``advise_scenario`` runs the full auto-tuner
(``repro.tuning``) for a NAMED catalog workload class under a degradation
budget — "my traffic looks like dc-onoff and I can afford 1%" comes back
as tuned knob settings plus the frontier they sit on.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core.eee import Policy, PowerModel
from repro.core.simulator import compare_policies
from repro.topology.megafly import Megafly, paper_topology, small_topology
from repro.traffic import collectives as C
from repro.traffic.trace import Trace

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

PEAK_FLOPS = 197e12          # TPU v5e bf16 / chip


def load_cell(arch: str, shape: str, mesh: str = "16x16",
              dryrun_dir=DRYRUN_DIR) -> dict:
    pod = "pod2" if mesh.startswith("2x") else "pod1"
    path = Path(dryrun_dir) / f"{arch}__{shape}__{pod}.json"
    rec = json.loads(path.read_text())
    if rec.get("status") != "ok":
        raise ValueError(f"cell {path.name} is {rec.get('status')}: "
                         f"{rec.get('reason', rec.get('error'))}")
    return rec


def _tp_dp_split(census: dict):
    """(tp_bytes, dp_bytes) logical bytes per device per step.

    Prefers the census's replica-group axis classification (contiguous
    groups = model axis = TP/EP/SP; strided = data/pod = DP); falls back
    to op-kind (all-reduce = DP) for censuses recorded without it."""
    axis = census.get("per_axis")
    if axis:
        tp = axis.get("tp", 0.0) + axis.get("local", 0.0)
        dp = axis.get("dp", 0.0)
        return tp, dp
    per_op = census.get("per_op", {})
    dp = per_op.get("all-reduce", 0.0)
    tp = sum(v for k, v in per_op.items() if k != "all-reduce")
    return tp, dp


def llm_trace_from_cell(rec: dict, topo: Megafly, *, n_steps: int = 3,
                        tp_degree: int = 16, mfu: float = 0.4,
                        node_offset: int = 0) -> Trace:
    """Build a Megafly trace replaying ``n_steps`` of the compiled job."""
    n_dev = rec["n_devices"]
    assert node_offset + n_dev <= topo.n_nodes
    nodes = np.arange(node_offset, node_offset + n_dev, dtype=np.int64)
    census = rec["collectives"]
    layers = max(list(census.get("while_trip_counts", {}).values()) or [1])
    tp_bytes, dp_bytes = _tp_dp_split(census)
    flops = rec["cost"].get("flops", 0.0)
    step_secs = flops / (PEAK_FLOPS * mfu) if flops else 1e-3

    # Small cells: a TP group can never outgrow the cell.  Without the
    # clamp an 8-device cell with the default tp_degree=16 builds strided
    # dp_groups where ranks >= n_dev are EMPTY arrays (and TP allreduce
    # rounds over a non-power-of-two remainder), so clamp to the largest
    # power of two that fits and let the existing len>=2 guard skip the
    # DP phase when the cell has no data-parallel replication at all.
    eff_tp = min(tp_degree, n_dev)
    eff_tp = 1 << (eff_tp.bit_length() - 1)      # collectives need 2**k
    tp_groups = [nodes[i:i + eff_tp]
                 for i in range(0, n_dev, eff_tp)]
    dp_groups = [nodes[r::eff_tp] for r in range(eff_tp)]
    per_layer = max(int(tp_bytes / max(layers, 1)), 1)

    t = Trace(nodes=nodes, name=f"llm/{rec['arch']}/{rec['shape']}")
    tp_rounds = _merged_allreduce(tp_groups, per_layer)
    dp_rounds = _merged_allreduce(dp_groups, max(int(dp_bytes), 1))
    for _ in range(n_steps):
        comp = step_secs / max(layers, 1)
        for _l in range(layers):
            t.compute(comp)
            if tp_bytes > 0 and tp_rounds:
                t.rounds(tp_rounds)
        if dp_bytes > 0 and dp_rounds:
            t.rounds(dp_rounds, barrier_last=True)
        else:
            t.barrier()
    return t


def _merged_allreduce(groups, nbytes: int) -> list:
    """Ring-allreduce rounds over ``groups``, merged round-by-round so the
    groups run in parallel.  Degenerate groups — empty, singleton, or a
    non-power-of-two remainder the ring collective cannot express — are
    dropped instead of being handed to ``collectives.allreduce`` (which
    asserts 2**k participants), and ragged round counts are merged with
    ``zip_longest`` so a short remainder group never silently truncates
    the longer groups' rounds."""
    import itertools
    per = [C.allreduce(np.asarray(g), nbytes) for g in groups
           if len(g) >= 2 and (len(g) & (len(g) - 1)) == 0]
    if not per:
        return []
    merged = []
    for ring in itertools.zip_longest(*per):
        live = [r for r in ring if r is not None]
        merged.append(live[0] if len(live) == 1
                      else np.concatenate(live))
    return merged


DEFAULT_POLICIES = {
    "fixed_fw_100us": Policy(kind="fixed", t_pdt=100e-6,
                             sleep_state="fast_wake"),
    "fixed_ds_100us": Policy(kind="fixed", t_pdt=100e-6,
                             sleep_state="deep_sleep"),
    "perfbound_1pct": Policy(kind="perfbound", bound=0.01,
                             sleep_state="deep_sleep"),
    "pbc_1pct": Policy(kind="perfbound_correct", bound=0.01,
                       sleep_state="deep_sleep"),
    "pbc_1pct_fw": Policy(kind="perfbound_correct", bound=0.01,
                          sleep_state="fast_wake"),
}


def advise_scenario(scenario: str, *, budget_pct: float = 1.0,
                    topo=None, n_nodes: int | None = None, rounds: int = 3,
                    space=None, objective: str = "link_energy",
                    pm: PowerModel | None = None) -> dict:
    """Recommend a power policy for a named catalog workload class.

    The scenario-name front door to the auto-tuner (``repro.tuning``):
    an operator who knows their workload resembles e.g. ``dc-onoff`` and
    can tolerate ``budget_pct`` percent slowdown gets back the tuned knob
    settings plus the energy/degradation frontier those knobs sit on —
    without a dry-run artifact.  Defaults to the 80-node small Megafly
    (CPU-friendly); pass ``topo=paper_topology()`` for the §4 system.

    Returns ``{'scenario', 'budget_pct', 'recommended', 'policy',
    'frontier', 'rounds'}`` where ``policy`` is the winning
    :class:`~repro.core.eee.Policy` (None when only the always-on
    baseline fits the budget) and ``frontier`` rows carry the §4
    percentages per non-dominated point.
    """
    from repro.scenarios import get_scenario
    from repro.tuning import tune_scenarios
    get_scenario(scenario)               # fail loudly on unknown names
    topo = topo if topo is not None else small_topology()
    report = tune_scenarios(topo, [scenario], budget_pct=budget_pct,
                            rounds=rounds, space=space, n_nodes=n_nodes,
                            objective=objective, pm=pm)
    tuning = report.scenarios[scenario]
    w = tuning.winner
    return {
        "scenario": scenario,
        "budget_pct": budget_pct,
        "recommended": w.name,
        "policy": w.policy,
        "row": w.row,
        "frontier": [{"policy": p.name, "degradation_pct": p.degradation,
                      **{k: p.row[k] for k in ("energy_saved_pct",
                                               "link_energy_saved_pct")}}
                     for p in tuning.frontier],
        "rounds": report.rounds,
    }


def advise_stream(drift: str, *, budget_pct: float = 0.1,
                  topo=None, n_nodes: int | None = None,
                  windows: int | None = None, seed: int | None = None,
                  pool=None, pool_size: int = 6, pool_rounds: int = 2,
                  margin_pct: float = 5.0, min_dwell: int = 2,
                  objective: str = "link_energy",
                  pm: PowerModel | None = None, **kw) -> dict:
    """Run the closed-loop streaming advisor on a named drift stream.

    The live-traffic front door (DESIGN.md §11): where ``advise_scenario``
    answers "my traffic looks like dc-onoff" ONCE, this follows a DRIFTING
    arrival process (``repro.streaming.drift`` catalog: diurnal sine,
    flash crowds, regime switching) window by window, racing the incumbent
    against a tuned challenger pool and switching with hysteresis under
    the degradation budget.  Returns the ``repro.streaming.advise_stream``
    report — per-window timeline plus online-vs-best-static totals.
    """
    from repro.streaming import advise_stream as _advise_stream
    from repro.streaming import get_drift
    spec = get_drift(drift).scaled(n_nodes=n_nodes, windows=windows,
                                   seed=seed)
    topo = topo if topo is not None else small_topology()
    return _advise_stream(spec, topo, budget_pct=budget_pct, pool=pool,
                          pool_size=pool_size, pool_rounds=pool_rounds,
                          margin_pct=margin_pct, min_dwell=min_dwell,
                          objective=objective, pm=pm, **kw)


def print_stream_report(out: dict) -> None:
    """Render an ``advise_stream`` report as the CLI/experiment table."""
    print(f"stream: {out['stream']} ({out['drift']}, "
          f"{out['windows']} windows)  budget <= "
          f"{out['budget_pct']:g}% overhead  objective={out['objective']}")
    print(f"pool: {', '.join(out['pool'])}")
    print(f"  {'w':>3s} {'rate/s':>8s} {'incumbent':28s} {'ovh%':>7s} "
          f"{'saved%':>7s} {'compiles':>8s}  switch")
    for r in out["timeline"]:
        sw = f"-> {r['next_incumbent']} ({r['reason']})" \
            if r["switched"] else ""
        print(f"  {r['window']:3d} {r['rate']:8.0f} {r['incumbent']:28s} "
              f"{r['overhead_pct']:7.3f} {r['saved_pct']:7.2f} "
              f"{r['compiles']:8d}  {sw}")
    t = out["totals"]
    print(f"switches: {out['switches']}   final incumbent: "
          f"{out['final_incumbent']}")
    print(f"online:      saved={t['online_saved_pct']:6.2f}%  "
          f"ovh={t['online_overhead_pct']:.3f}%")
    print(f"best static: saved={t['best_static_saved_pct']:6.2f}%  "
          f"({t['best_static']})")
    print(f"gain vs best-static-in-hindsight: "
          f"{t['gain_vs_static_pct']:.2f}%")


def advise(arch: str, shape: str, mesh: str = "16x16", *,
           policies: dict | None = None, n_steps: int = 3,
           mfu: float = 0.4, max_overhead_pct: float = 1.0,
           topo: Megafly | None = None, pm: PowerModel | None = None,
           dryrun_dir=DRYRUN_DIR) -> dict:
    """Evaluate policies for a dry-run cell.  Returns
    {'cell', 'table', 'recommended'} — recommended = most total energy
    saved subject to exec overhead <= max_overhead_pct; when no policy
    fits the budget the recommendation falls back to the always-on
    ``"baseline"`` row (0% overhead, 0% saved), mirroring
    ``frontier.budget_winner`` — the advisor never answers None."""
    rec = load_cell(arch, shape, mesh, dryrun_dir)
    topo = topo or paper_topology()
    trace = llm_trace_from_cell(rec, topo, n_steps=n_steps, mfu=mfu)
    table = compare_policies(trace, topo, policies or DEFAULT_POLICIES, pm)
    best, best_saved = "baseline", 0.0
    for name, row in table.items():
        if name == "baseline":
            continue
        if row["exec_overhead_pct"] <= max_overhead_pct \
                and row["energy_saved_pct"] > best_saved:
            best, best_saved = name, row["energy_saved_pct"]
    return {
        "cell": {k: rec[k] for k in ("arch", "shape", "mesh", "n_devices")},
        "tp_dp_bytes": _tp_dp_split(rec["collectives"]),
        "table": table,
        "recommended": best,
    }


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default=None,
                    help="dry-run cell mode: compiled-job architecture")
    ap.add_argument("--scenario", default=None, metavar="NAME",
                    help="catalog mode: tune for a named workload class "
                         "(repro.scenarios catalog) instead of a dry-run "
                         "cell")
    ap.add_argument("--stream", default=None, metavar="DRIFT",
                    help="streaming mode: follow a named drifting stream "
                         "(repro.streaming drift catalog) with the "
                         "closed-loop online advisor")
    ap.add_argument("--budget", type=float, default=None, metavar="PCT",
                    help="scenario/stream mode: max exec overhead in "
                         "percent (default 1.0 scenario, 0.1 stream)")
    ap.add_argument("--rounds", type=int, default=3,
                    help="scenario mode: tuner search rounds")
    ap.add_argument("--windows", type=int, default=None,
                    help="stream mode: override the drift's window count")
    ap.add_argument("--n-nodes", type=int, default=None,
                    help="scenario/stream mode: allocation size")
    ap.add_argument("--small-topo", action="store_true",
                    help="stream mode: tiny 12-node Megafly (CI smoke)")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--max-overhead-pct", type=float, default=1.0)
    args = ap.parse_args()
    modes = [m for m in (args.arch, args.scenario, args.stream)
             if m is not None]
    if len(modes) != 1:
        ap.error("pass exactly one of --arch (dry-run cell), --scenario "
                 "(catalog workload) or --stream (drifting stream)")
    if args.stream:
        topo = small_topology(n_groups=3, leaves=2, spines=2,
                              nodes_per_leaf=2) if args.small_topo else None
        out = advise_stream(
            args.stream,
            budget_pct=0.1 if args.budget is None else args.budget,
            topo=topo, n_nodes=args.n_nodes, windows=args.windows)
        print_stream_report(out)
        return
    if args.scenario:
        out = advise_scenario(args.scenario,
                              budget_pct=1.0 if args.budget is None
                              else args.budget,
                              rounds=args.rounds, n_nodes=args.n_nodes)
        print(f"scenario: {out['scenario']}  "
              f"budget <= {out['budget_pct']:g}% overhead")
        for p in out["frontier"]:
            print(f"  {p['policy']:34s} "
                  f"ovh={p['degradation_pct']:7.3f}% "
                  f"saved={p['energy_saved_pct']:6.2f}% "
                  f"link_saved={p['link_energy_saved_pct']:6.2f}%")
        print(f"recommended: {out['recommended']}")
        if out["policy"] is not None:
            print(f"  policy: {out['policy']}")
        return
    out = advise(args.arch, args.shape, args.mesh, n_steps=args.steps,
                 max_overhead_pct=args.max_overhead_pct)
    print(f"cell: {out['cell']}")
    tp, dp = out["tp_dp_bytes"]
    print(f"wire bytes/device/step: TP={tp/2**20:.1f} MiB "
          f"DP={dp/2**20:.1f} MiB")
    for name, row in out["table"].items():
        print(f"  {name:18s} exec_oh={row['exec_overhead_pct']:7.3f}% "
              f"saved={row['energy_saved_pct']:6.2f}% "
              f"link_saved={row['link_energy_saved_pct']:6.2f}%")
    print(f"recommended: {out['recommended']}")


if __name__ == "__main__":
    main()
