"""Abstract input specs (ShapeDtypeStruct) + shardings for every cell kind.

``input_specs`` mirrors the real data pipeline / serving request batch
shape-for-shape, dtype-for-dtype, with zero device allocation — the dry-run
contract.  Modality frontends are stubs: the VLM cell receives precomputed
patch embeddings, the audio cell precomputed frame embeddings.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed import sharding as sh
from repro.models import model as M
from repro.serving.serve import make_prefill_step, make_serve_step
from repro.training.loop import abstract_train_state, make_train_step

# 30 s of audio = 1500 post-conv frames, padded to the 16-way model axis
# (jit input shardings require even tiling; the stub frontend zero-pads the
# trailing 4 frames, masked in a real deployment by the frontend's mask).
WHISPER_CROSS_LEN = 1504


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, with_labels: bool):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32)}
    if with_labels:
        batch["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sds((B, cfg.num_patches, cfg.d_model),
                                     cfg.dtype)
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, S, cfg.d_model), cfg.dtype)
    return batch


def serve_param_specs(cfg: ModelConfig):
    """Inference weights: bf16 copies of the float params."""
    p = jax.eval_shape(partial(M.init_params, cfg), jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda l: _sds(l.shape, jnp.bfloat16
                       if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype),
        p)


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: M.init_cache(cfg, B, S, enc_len=WHISPER_CROSS_LEN,
                             dtype=jnp.bfloat16))


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Returns (fn, args, in_shardings, out_shardings, donate) for lowering."""
    B = shape.global_batch
    if shape.kind == "train":
        state = abstract_train_state(cfg)
        batch = batch_specs(cfg, shape, with_labels=True)
        pspecs = sh.param_shardings(state["params"], mesh)
        state_sh = {"params": pspecs,
                    "opt": {"m": pspecs, "v": pspecs,
                            "step": sh.replicated(mesh)}}
        batch_sh = sh.batch_shardings(batch, mesh, B)
        fn = make_train_step(cfg)
        metrics_sh = jax.tree.map(
            lambda _: sh.replicated(mesh),
            {"loss": 0, "grad_norm": 0, "ce": 0, "aux": 0})
        return (fn, (state, batch), (state_sh, batch_sh),
                (state_sh, metrics_sh), (0,))

    params = serve_param_specs(cfg)
    pshard = sh.param_shardings(params, mesh)
    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape, with_labels=False)
        batch_sh = sh.batch_shardings(batch, mesh, B)
        fn = make_prefill_step(cfg)
        cache = cache_specs(cfg, shape)
        out_sh = (sh.batch_shardings(_sds((B,), jnp.int32), mesh, B),
                  sh.cache_shardings(cache, mesh, B))
        return fn, (params, batch), (pshard, batch_sh), out_sh, ()

    # decode: one new token with a KV cache holding seq_len-1 prior tokens
    cache = cache_specs(cfg, shape)
    cache_sh = sh.cache_shardings(cache, mesh, B)
    tokens = _sds((B, 1), jnp.int32)
    tok_sh = sh.batch_shardings(tokens, mesh, B)
    fn = make_serve_step(cfg)
    return (fn, (params, cache, tokens), (pshard, cache_sh, tok_sh),
            (tok_sh, cache_sh), (1,))
