"""Training launcher: config -> mesh -> sharded train loop with
checkpoint/restart, async saves, straggler monitoring, and optional
cross-pod gradient compression.

On a real TPU fleet this process runs once per host (jax.distributed
initializes from the cluster env) and the mesh spans all pods; on CPU (CI,
this container) it runs the same code on a (n_devices, 1) local mesh with
the arch's reduced ``--smoke`` config — the e2e example and tests drive it
that way.

XLA flags for TPU runs (latency-hiding scheduler overlaps the per-layer
TP collectives with compute — see EXPERIMENTS.md §Perf):
  --xla_tpu_enable_latency_hiding_scheduler=true
  --xla_tpu_enable_async_collective_fusion=true
are exported via REPRO_XLA_EXTRA so the dry-run can A/B them.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_config
from repro.data.pipeline import make_pipeline
from repro.distributed import sharding as sh
from repro.distributed.fault import StragglerMonitor
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.training.loop import init_train_state, make_train_step
from repro.training.optimizer import AdamWConfig

TPU_XLA_FLAGS = ("--xla_tpu_enable_latency_hiding_scheduler=true "
                 "--xla_tpu_enable_async_collective_fusion=true")


def build(cfg, mesh, *, lr, grad_accum, seed=0):
    """Returns (state, step_fn, state_shardings)."""
    with mesh:
        state = init_train_state(cfg, jax.random.PRNGKey(seed))
    pspecs = sh.param_shardings(state["params"], mesh)
    state_sh = {"params": pspecs,
                "opt": {"m": pspecs, "v": pspecs,
                        "step": sh.replicated(mesh)}}
    step_fn = jax.jit(
        make_train_step(cfg, AdamWConfig(lr=lr), grad_accum=grad_accum),
        in_shardings=(state_sh, None), out_shardings=(state_sh, None),
        donate_argnums=(0,))
    state = jax.device_put(state, state_sh)
    return state, step_fn, state_sh


def train(cfg, *, steps, seq_len, global_batch, lr=3e-4, grad_accum=1,
          ckpt_dir=None, save_every=50, resume=False, log_every=10,
          mesh=None, log=print):
    mesh = mesh or make_local_mesh()
    state, step_fn, state_sh = build(cfg, mesh, lr=lr,
                                     grad_accum=grad_accum)
    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    start = 0
    if mgr and resume and mgr.latest_step() is not None:
        state, start, _ = mgr.restore(state, shardings=state_sh)
        log(f"resumed from step {start}")

    monitor = StragglerMonitor(jax.process_count() or 1)
    it = make_pipeline(cfg, seq_len, global_batch, start_step=start,
                       shard=jax.process_index(),
                       num_shards=max(jax.process_count(), 1))
    losses = []
    t_step = time.perf_counter()
    with mesh:
        for step, batch in it:
            if step >= steps:
                break
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t_step
            t_step = time.perf_counter()
            monitor.observe(step, {jax.process_index(): dt})
            if step % log_every == 0:
                log(f"step {step:5d} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"{dt*1e3:.0f} ms")
            if mgr and (step + 1) % save_every == 0:
                mgr.save_async(state, step + 1)
    if hasattr(it, "close"):
        it.close()
    if mgr:
        mgr.wait()
        mgr.save(state, min(steps, step + 1))
    return state, losses


def main():
    ap = argparse.ArgumentParser(description="repro train launcher")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (data=16, model=16) mesh (TPU pod)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_local_mesh())
    t0 = time.time()
    _, losses = train(cfg, steps=args.steps, seq_len=args.seq_len,
                      global_batch=args.global_batch, lr=args.lr,
                      grad_accum=args.grad_accum, ckpt_dir=args.ckpt_dir,
                      save_every=args.save_every, resume=args.resume,
                      mesh=mesh)
    print(f"done: {len(losses)} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}")


if __name__ == "__main__":
    main()
