"""Production meshes.  Functions only — importing this module never touches
jax device state; ``dryrun.py`` sets XLA_FLAGS for 512 placeholder devices
before any jax import."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a (data, model) mesh — used by
    examples and tests on CPU."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
